//! Sharded serving pool contracts (DESIGN.md §8), all runnable with no
//! artifacts: the sim runtime backend (`artifacts_dir = "sim"`) stands in
//! for the PJRT executables with a deterministic host-side model.
//!
//! * **Determinism** — per-tag outputs are bit-identical at any shard
//!   count (sessions are independent; seeds derive from request content,
//!   not admission order), and identical to a bare engine run.
//! * **Admission** — the dispatcher is the single admission point:
//!   `queue_depth` is the exact waiting-request boundary, rejections are
//!   submit-time errors, and malformed requests never reach a shard.
//! * **Decode accounting** — `max_new` boundaries enforced; the compress
//!   histogram no longer double-counts decode wall time.

use zipcache::config::EngineConfig;
use zipcache::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use zipcache::coordinator::{CancelToken, Engine, FinishReason,
                            GenerationRequest, Priority, QuantOverride};
use zipcache::kvcache::worst_case_resident_bytes;
use zipcache::server::{loadgen, Server};
use zipcache::simcost::{decode_cost_per_token, prefill_cost, AttnKind,
                        AttnShape, Hardware};
use zipcache::workload::{Task, TaskGen};

fn sim_config(shards: usize) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").unwrap();
    cfg.scheduler.shards = shards;
    cfg.parallelism = 1; // pool-width parity is pinned in parallel_parity.rs
    cfg
}

fn prompts(n: usize) -> Vec<Vec<u16>> {
    let gen = TaskGen::new(Task::Code, 60);
    (0..n).map(|i| gen.sample(i as u64).prompt().to_vec()).collect()
}

#[test]
fn per_tag_outputs_identical_across_shard_counts() {
    let ps = prompts(6);
    let run = |shards: usize| -> Vec<(Vec<u16>, usize, f64)> {
        let mut cfg = sim_config(shards);
        cfg.quant.recompress_every = 4; // several streaming cycles per request
        let server = Server::start(cfg).unwrap();
        let handles: Vec<_> = ps
            .iter()
            .map(|p| server.handle.submit(p.clone(), 8).unwrap())
            .collect();
        let outs: Vec<_> = handles
            .into_iter()
            .map(|h| {
                let o = h.wait().unwrap();
                (o.tokens, o.cache_bytes, o.compression_ratio)
            })
            .collect();
        server.shutdown().unwrap();
        outs
    };
    let one = run(1);
    assert!(one.iter().all(|(t, _, _)| !t.is_empty()));
    assert_eq!(one, run(2), "2 shards changed per-request outputs");
    assert_eq!(one, run(4), "4 shards changed per-request outputs");
}

#[test]
fn server_outputs_match_bare_engine() {
    // Scheduling through the pool must be invisible: the same request
    // through a bare engine yields the same tokens.
    let ps = prompts(3);
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let direct: Vec<Vec<u16>> = ps
        .iter()
        .map(|p| engine.generate(p, 5).unwrap().tokens)
        .collect();
    let server = Server::start(sim_config(2)).unwrap();
    // submit in reverse order: admission order must not matter either
    let served: Vec<Vec<u16>> = {
        let handles: Vec<_> = ps
            .iter()
            .rev()
            .map(|p| server.handle.submit(p.clone(), 5).unwrap())
            .collect();
        let mut outs: Vec<_> =
            handles.into_iter().map(|h| h.wait().unwrap().tokens).collect();
        outs.reverse();
        outs
    };
    server.shutdown().unwrap();
    assert_eq!(direct, served);
}

#[test]
fn smoke_two_shards_complete_all_requests() {
    let server = Server::start(sim_config(2)).unwrap();
    assert_eq!(server.handle.shards(), 2);
    let mut handles = Vec::new();
    for p in prompts(6) {
        handles.push(server.handle.submit(p, 3).unwrap());
    }
    for h in handles {
        let out = h.wait().unwrap();
        assert!(!out.tokens.is_empty() && out.tokens.len() <= 3);
    }
    let snap = server.handle.metrics();
    assert_eq!(snap.shards(), 2);
    assert_eq!(snap.total.requests_completed, 6);
    assert_eq!(
        snap.per_shard.iter().map(|m| m.requests_completed).sum::<u64>(),
        6,
        "per-shard breakdown must sum to the total"
    );
    assert!(snap.total.prefill.count() >= 6);
    server.shutdown().unwrap();
}

#[test]
fn max_new_boundaries() {
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let p = prompts(1).remove(0);
    // max_new = 0 is rejected at session start (the old off-by-one would
    // have emitted one token anyway)...
    assert!(engine
        .start_session(GenerationRequest::new(p.clone(), 0))
        .is_err());
    // ...and the server rejects it at submit time, before it can poison a
    // shard.
    let server = Server::start(sim_config(1)).unwrap();
    assert!(server.handle.submit(p.clone(), 0).is_err());
    assert!(server.handle.submit(Vec::new(), 3).is_err());
    // Window overflow is also a submit-time error (micro window = 64),
    // and the rejection must not consume an admission slot or poison the
    // shard: a well-formed request right after still completes.
    assert!(server.handle.submit(p.clone(), 64).is_err());
    assert_eq!(server.handle.queued() + server.handle.shard_loads()[0], 0);
    // max_new = 1 emits exactly one token.
    let out = engine.generate(&p, 1).unwrap();
    assert_eq!(out.tokens.len(), 1);
    let out = server.handle.generate(p, 1).unwrap();
    assert_eq!(out.tokens.len(), 1);
    server.shutdown().unwrap();
}

#[test]
fn overload_rejects_at_submit_time() {
    let mut cfg = sim_config(1);
    cfg.scheduler.max_batch = 1;
    cfg.scheduler.queue_depth = 1;
    let server = Server::start(cfg).unwrap();
    let ps = prompts(8);
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for p in ps {
        match server.handle.submit(p, 16) {
            Ok(h) => accepted.push(h),
            Err(e) => {
                assert!(e.to_string().contains("queue full"), "{e}");
                rejected += 1;
            }
        }
    }
    // One decode slot + one waiting slot: back-to-back submission of 8
    // requests must hit backpressure (a shard can activate at most one
    // request before the loop finishes submitting).
    assert!(rejected >= 1, "no submit-time backpressure observed");
    let completed = accepted.len();
    for h in accepted {
        h.wait().unwrap();
    }
    assert_eq!(completed + rejected, 8);
    server.shutdown().unwrap();
}

#[test]
fn start_fails_fast_on_unloadable_artifacts() {
    let mut cfg = sim_config(2);
    cfg.artifacts_dir = "definitely_missing_artifacts_dir".into();
    assert!(Server::start(cfg).is_err());
}

#[test]
fn batcher_interleaves_over_sim_engine() {
    // The artifact-gated engine_e2e batcher test, runnable everywhere.
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let mut b = ContinuousBatcher::new(2, 8);
    for (tag, p) in prompts(5).into_iter().enumerate() {
        b.submit(QueuedRequest {
            request: GenerationRequest::new(p, 3),
            tag: tag as u64,
        })
        .unwrap();
    }
    let outcomes = b.run_to_completion(&mut engine).unwrap();
    assert_eq!(outcomes.len(), 5);
    assert!(outcomes.iter().all(|o| !o.tokens.is_empty()));
    assert_eq!(engine.metrics.requests_completed, 5);
}

#[test]
fn decode_histogram_excludes_recompression_span() {
    // Pin the accounting fix: per-step decode samples exclude the
    // recompression block, so sum(decode) + sum(compress) cannot exceed
    // the session's total decode wall time.  (The old code recorded the
    // full step span into *both* histograms — sums then overshoot as soon
    // as a cycle fires.)
    let mut cfg = sim_config(1);
    cfg.quant.recompress_every = 2;
    let mut engine = Engine::new(cfg).unwrap();
    let mut session_decode_ms = 0.0;
    for p in prompts(4) {
        session_decode_ms += engine.generate(&p, 12).unwrap().decode_ms;
    }
    let m = &engine.metrics;
    assert!(m.compress.count() >= 1, "expected recompression cycles");
    let decode_total = m.decode.mean_ms() * m.decode.count() as f64;
    let compress_total = m.compress.mean_ms() * m.compress.count() as f64;
    assert!(
        decode_total + compress_total <= session_decode_ms + 0.2,
        "histograms double-count: decode {decode_total:.3}ms + compress \
         {compress_total:.3}ms > sessions {session_decode_ms:.3}ms"
    );
}

#[test]
fn streaming_recompression_triggers_on_sim() {
    let mut cfg = sim_config(1);
    cfg.quant.recompress_every = 4;
    let mut engine = Engine::new(cfg).unwrap();
    for p in prompts(3) {
        let mut sess = engine
            .start_session(GenerationRequest::new(p, 16))
            .unwrap();
        while !sess.is_done() {
            engine.decode_step(&mut sess).unwrap();
        }
    }
    assert!(engine.metrics.compress.count() >= 1, "recompression never fired");
}

// ---- typed request/response API (DESIGN.md §11) ---------------------------

#[test]
fn default_request_matches_legacy_submit_across_shards() {
    // Acceptance pin: a GenerationRequest built with all defaults is
    // bit-identical to the legacy submit(prompt, max_new) path at
    // shards ∈ {1, 2, 4} — and both match a bare engine run.
    let ps = prompts(6);
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let bare: Vec<Vec<u16>> = ps
        .iter()
        .map(|p| engine.generate(p, 8).unwrap().tokens)
        .collect();
    for shards in [1usize, 2, 4] {
        let server = Server::start(sim_config(shards)).unwrap();
        let legacy: Vec<_> = ps
            .iter()
            .map(|p| server.handle.submit(p.clone(), 8).unwrap())
            .collect();
        let typed: Vec<_> = ps
            .iter()
            .map(|p| {
                server
                    .handle
                    .submit_request(GenerationRequest::new(p.clone(), 8))
                    .unwrap()
            })
            .collect();
        let legacy: Vec<Vec<u16>> =
            legacy.into_iter().map(|h| h.wait().unwrap().tokens).collect();
        let typed: Vec<Vec<u16>> =
            typed.into_iter().map(|h| h.wait().unwrap().tokens).collect();
        assert_eq!(legacy, bare, "shards={shards}: legacy path diverged");
        assert_eq!(typed, bare, "shards={shards}: defaults-built request \
                                 diverged from the legacy path");
        server.shutdown().unwrap();
    }
}

#[test]
fn streamed_tokens_concatenate_to_final_response() {
    let server = Server::start(sim_config(2)).unwrap();
    for p in prompts(4) {
        let mut h = server
            .handle
            .submit_request(GenerationRequest::new(p, 6))
            .unwrap();
        let mut streamed = Vec::new();
        while let Some(tok) = h.next_token() {
            streamed.push(tok);
        }
        let out = h.wait().unwrap();
        assert_eq!(streamed, out.tokens,
                   "streamed tokens must concatenate to the final tokens");
        assert!(matches!(out.finish,
                         FinishReason::Eos | FinishReason::MaxTokens));
        assert!(!out.tokens.is_empty() && out.tokens.len() <= 6);
    }
    server.shutdown().unwrap();
}

#[test]
fn finish_reasons_cover_budget_and_window() {
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let p = prompts(1).remove(0);
    // Tiny budget: deterministic MaxTokens (EOS-free sim trajectories
    // would need the budget; a natural EOS inside 1 token is an Eos —
    // accept both, but the reason must match the token count).
    let out = engine.generate(&p, 1).unwrap();
    match out.finish {
        FinishReason::MaxTokens => assert_eq!(out.tokens.len(), 1),
        FinishReason::Eos => assert!(out.tokens.len() <= 1),
        other => panic!("unexpected finish reason {other:?}"),
    }
    assert_eq!(out.tag, 0, "bare-engine responses carry tag 0");
}

#[test]
fn stop_tokens_finish_with_eos() {
    // Generate unconstrained once, then re-run with the first emitted
    // token as a stop token: generation must finish immediately with
    // FinishReason::Eos after that token.
    let p = prompts(1).remove(0);
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let free = engine.generate(&p, 8).unwrap();
    assert!(!free.tokens.is_empty());
    let stop = free.tokens[0];
    let mut engine2 = Engine::new(sim_config(1)).unwrap();
    let stopped = engine2
        .generate_request(GenerationRequest::new(p, 8).stop_token(stop))
        .unwrap();
    assert_eq!(stopped.tokens, vec![stop]);
    assert_eq!(stopped.finish, FinishReason::Eos);
}

#[test]
fn seed_override_changes_trajectory_determinism_preserved() {
    // Same content + same override => identical outputs; the override
    // feeds the content-derived mix, so determinism is per (seed, content).
    let p = prompts(1).remove(0);
    let run = |seed: Option<u64>| -> Vec<u16> {
        let mut engine = Engine::new(sim_config(1)).unwrap();
        let mut req = GenerationRequest::new(p.clone(), 8);
        if let Some(s) = seed {
            req = req.seed(s);
        }
        engine.generate_request(req).unwrap().tokens
    };
    assert_eq!(run(None), run(Some(0)),
               "seed override 0 must equal the engine default (cfg.seed = 0)");
    assert_eq!(run(Some(7)), run(Some(7)));
}

#[test]
fn quant_override_is_live_and_validated() {
    // An 8/8-bit override must change the compressed footprint versus
    // the default 4/2 mix (proving the override reaches the policy), and
    // malformed overrides are submit-time errors.
    let p = prompts(1).remove(0);
    let mut cfg = sim_config(1);
    cfg.quant.recompress_every = 4;
    let mut engine = Engine::new(cfg.clone()).unwrap();
    let dflt = engine.generate(&p, 8).unwrap();
    let mut engine2 = Engine::new(cfg).unwrap();
    let wide = engine2
        .generate_request(GenerationRequest::new(p.clone(), 8).quant(
            QuantOverride { bits_high: 8, bits_low: 8, saliency_ratio: 1.0 },
        ))
        .unwrap();
    assert!(wide.cache_bytes > dflt.cache_bytes,
            "8-bit override did not grow the compressed footprint \
             ({} vs {})", wide.cache_bytes, dflt.cache_bytes);
    assert!(wide.compression_ratio < dflt.compression_ratio);

    let server = Server::start(sim_config(1)).unwrap();
    let bad = GenerationRequest::new(p, 4).quant(QuantOverride {
        bits_high: 3,
        bits_low: 2,
        saliency_ratio: 0.5,
    });
    let err = server.handle.submit_request(bad).unwrap_err();
    assert!(err.to_string().contains("bits_high"), "{err}");
    server.shutdown().unwrap();
}

#[test]
fn priority_orders_the_staging_queue() {
    // One decode slot; three requests staged before the first step:
    // Interactive must activate (and therefore complete) before Batch,
    // Batch before Background, regardless of submission order.
    let mut cfg = sim_config(1);
    cfg.scheduler.max_batch = 1;
    let mut engine = Engine::new(cfg).unwrap();
    let mut b = ContinuousBatcher::new(1, 8);
    let ps = prompts(3);
    let classes = [Priority::Background, Priority::Interactive, Priority::Batch];
    for (tag, (p, &prio)) in ps.into_iter().zip(&classes).enumerate() {
        b.submit(QueuedRequest {
            request: GenerationRequest::new(p, 3).priority(prio),
            tag: tag as u64,
        })
        .unwrap();
    }
    let mut order = Vec::new();
    while !b.idle() {
        b.step(&mut engine).unwrap();
        for o in b.take_outcomes() {
            order.push(o.tag);
        }
    }
    assert_eq!(order, vec![1, 2, 0],
               "completion order must follow priority classes");
}

#[test]
fn shared_validation_rejects_identically_at_both_layers() {
    // The dedup satellite: Engine::start_session and ServerHandle submit
    // paths must produce the *same* rejection for the same bad request
    // (both call GenerationRequest::validate — they cannot drift).
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let server = Server::start(sim_config(1)).unwrap();
    let cases: Vec<GenerationRequest> = vec![
        GenerationRequest::new(Vec::new(), 3),
        GenerationRequest::new(vec![1], 0),
        GenerationRequest::new(vec![1; 60], 64),
        GenerationRequest::new(vec![1], 2).quant(QuantOverride {
            bits_high: 2,
            bits_low: 4,
            saliency_ratio: 0.5,
        }),
    ];
    for req in cases {
        let e1 = engine.start_session(req.clone()).unwrap_err().to_string();
        let e2 = server.handle.submit_request(req).unwrap_err().to_string();
        assert_eq!(e1, e2, "validation drifted between engine and server");
    }
    server.shutdown().unwrap();
}

// ---- chunked prefill interleaved with decode (DESIGN.md §12) --------------

/// Virtual per-unit costs from the `simcost` roofline at the engine's
/// model shape: (prefill seconds per prompt token, decode seconds per
/// step).  The fairness assertions below price scheduler iterations with
/// these — a deterministic clock, so the token-gap bound can never flake
/// on a loaded CI host the way wall time would.
fn virtual_costs(engine: &Engine) -> (f64, f64) {
    let lay = engine.layout();
    let shape = AttnShape {
        batch: 1,
        heads: lay.heads,
        seq: lay.seq,
        d_head: lay.d_head,
        elem: 2.0,
    };
    let hw = Hardware::a100();
    let per_tok_prefill =
        prefill_cost(hw, shape, AttnKind::FlashWithProbes { probe_pct: 10 })
            / lay.seq as f64;
    let decode = decode_cost_per_token(hw, shape, 2.8, AttnKind::Flash);
    (per_tok_prefill, decode)
}

fn p99(mut xs: Vec<f64>) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[((xs.len() - 1) as f64 * 0.99).round() as usize]
}

const BURST_CHUNK: usize = 4;
const N_INTERACTIVE: usize = 3;

/// Drive the long-prompt-burst scenario through `batcher.step` on a
/// virtual clock: three Interactive sessions decode while one Background
/// near-window prompt prefills.  Returns (p99 interactive token gap,
/// long prompt length, per-token prefill cost, per-step decode cost),
/// all in virtual seconds.
fn run_long_prompt_burst(greedy: bool) -> (f64, usize, f64, f64) {
    let mut cfg = sim_config(1);
    cfg.scheduler.max_batch = 8;
    cfg.scheduler.prefill_chunk = BURST_CHUNK;
    let mut engine = Engine::new(cfg).unwrap();
    let (per_tok_prefill, decode) = virtual_costs(&engine);
    let mut b = ContinuousBatcher::new(8, 16);
    b.force_greedy_prefill(greedy);

    for tag in 0..N_INTERACTIVE as u64 {
        let prompt: Vec<u16> = (0..9).map(|k| (10 * tag + k + 1) as u16).collect();
        b.submit(QueuedRequest {
            request: GenerationRequest::new(prompt, 24)
                .priority(Priority::Interactive),
            tag,
        })
        .unwrap();
    }

    // Virtual clock: every iteration costs its decode-artifact
    // executions plus the prompt tokens its prefill chunks covered;
    // tokens emitted in an iteration are stamped with the end-of-step
    // time (DESIGN.md §12).
    let mut vt = 0.0f64;
    let mut stamps: Vec<Vec<f64>> = vec![Vec::new(); N_INTERACTIVE];
    let mut step = |b: &mut ContinuousBatcher, engine: &mut Engine,
                    vt: &mut f64, stamps: &mut Vec<Vec<f64>>| {
        let report = b.step(engine).unwrap();
        *vt += report.decoded as f64 * decode
            + report.prefill_tokens as f64 * per_tok_prefill;
        for (tag, _tok) in b.drain_emitted() {
            if (tag as usize) < N_INTERACTIVE {
                stamps[tag as usize].push(*vt);
            }
        }
    };

    // Warm up until every Interactive session is streaming tokens.
    let mut guard = 0;
    while stamps.iter().any(|s| s.is_empty()) {
        step(&mut b, &mut engine, &mut vt, &mut stamps);
        guard += 1;
        assert!(guard < 64, "interactive sessions never started decoding");
    }

    // The burst: one Background near-window prompt (the sim-window
    // analogue of an 8k-token prefill).
    let long: Vec<u16> =
        TaskGen::new(Task::Lines(8), 56).sample(99).prompt().to_vec();
    let long_len = long.len();
    assert!(long_len > 8 * BURST_CHUNK, "long prompt must span many chunks");
    b.submit(QueuedRequest {
        request: GenerationRequest::new(long, 2).priority(Priority::Background),
        tag: 100,
    })
    .unwrap();
    while !b.idle() {
        step(&mut b, &mut engine, &mut vt, &mut stamps);
    }
    let outs = b.take_outcomes();
    assert_eq!(outs.len(), N_INTERACTIVE + 1);
    assert!(outs.iter().all(|o| o.finish.is_natural()));

    let gaps: Vec<f64> = stamps
        .iter()
        .flat_map(|s| s.windows(2).map(|w| w[1] - w[0]))
        .collect();
    (p99(gaps), long_len, per_tok_prefill, decode)
}

#[test]
fn long_prompt_burst_bounds_interactive_token_gaps() {
    // The headline fairness property (DESIGN.md §12): with chunked
    // prefill, a Background near-window prompt in flight never opens an
    // interactive token gap wider than one fair iteration — all
    // scheduled decodes plus *one* prefill chunk (plus the concurrent
    // interactive prefill chunks of the warm-up phase).  The bound is
    // placed at half the long prompt's prefill cost above the decode
    // term: far above any fair iteration (chunk = 4 tokens), far below a
    // monolithic/greedy one (the whole prompt in one step).
    let (gap_fair, long_len, per_tok, decode) = run_long_prompt_burst(false);
    let bound = (N_INTERACTIVE + 1) as f64 * decode
        + (long_len as f64 / 2.0) * per_tok;
    assert!(
        gap_fair <= bound,
        "fair schedule: interactive token-gap p99 {gap_fair:.3e}s exceeds \
         the bound {bound:.3e}s (long prompt starved decode)"
    );

    // Acceptance pin: the bound must *trip* when the scheduler is forced
    // to take every prefill chunk in one iteration — proving the
    // assertion really measures starvation, not slack.
    let (gap_greedy, _, _, _) = run_long_prompt_burst(true);
    assert!(
        gap_greedy > bound,
        "greedy prefill did not trip the bound ({gap_greedy:.3e}s <= \
         {bound:.3e}s) — the fairness test has no teeth"
    );
}

#[test]
fn long_prompt_burst_trace_completes_under_chunking() {
    // End-to-end smoke for the trace constructor + the serve path: the
    // long-prompt-burst trace replayed against a chunk-enabled sharded
    // server completes every request, and the chunked entries really ran.
    let mut cfg = sim_config(2);
    cfg.scheduler.prefill_chunk = 3;
    let server = Server::start(cfg).unwrap();
    let trace = loadgen::long_prompt_burst_trace(64, 5, 3, 0);
    assert_eq!(trace.len(), 5);
    assert_eq!(trace.entries[0].priority, Priority::Background);
    assert!(trace.entries[1..]
        .iter()
        .all(|e| e.priority == Priority::Interactive));
    assert!(trace.entries[0].sample.prompt().len()
        > trace.entries[1].sample.prompt().len());
    let report = loadgen::replay(&server.handle, &trace).unwrap();
    assert_eq!(report.completed, 5);
    let snap = server.handle.metrics();
    assert!(snap.total.prefill_chunks > 0, "no chunked prefill ran");
    assert_eq!(snap.total.prefill.count(), 5,
               "session-level prefill total: one sample per request");
    server.shutdown().unwrap();
}

#[test]
fn cancel_mid_prefill_releases_slot_and_partial_state() {
    // The PR-5 cancellation-leak pin, extended to the Prefilling phase:
    // a Background session cancelled between chunks must retire with
    // `Cancelled`, empty tokens, its pinned dense slot (and the boxed
    // PrefillProgress with it) released — and the survivor completes.
    let mut cfg = sim_config(1);
    cfg.scheduler.prefill_chunk = 2;
    let mut engine = Engine::new(cfg).unwrap();
    let free0 = engine.free_slots();
    let mut b = ContinuousBatcher::new(4, 16);

    // An Interactive decode session first: its presence makes the
    // Background prefill yield after one chunk per iteration, so the
    // cancel deterministically lands mid-prefill.
    b.submit(QueuedRequest {
        request: GenerationRequest::new(vec![3, 5, 7, 11], 20)
            .priority(Priority::Interactive),
        tag: 1,
    })
    .unwrap();
    let mut covered = 0usize;
    for _ in 0..4 {
        covered += b.step(&mut engine).unwrap().prefill_tokens;
    }
    assert_eq!(covered, 4, "interactive prompt fully prefilled");

    let long: Vec<u16> =
        TaskGen::new(Task::Lines(8), 56).sample(42).prompt().to_vec();
    let long_len = long.len();
    let cancel = CancelToken::new();
    b.submit(QueuedRequest {
        request: GenerationRequest::new(long, 2)
            .priority(Priority::Background)
            .cancel_token(cancel.clone()),
        tag: 0,
    })
    .unwrap();
    let mut bg_covered = 0usize;
    for _ in 0..3 {
        bg_covered += b.step(&mut engine).unwrap().prefill_tokens;
    }
    assert!(bg_covered > 0 && bg_covered < long_len,
            "cancel point must be mid-prefill ({bg_covered}/{long_len})");
    assert_eq!(engine.free_slots(), free0 - 2,
               "a Prefilling session pins a dense slot");

    cancel.cancel();
    let report = b.step(&mut engine).unwrap();
    assert_eq!(report.prefill_tokens, 0,
               "no further chunk may run after the cancel sweep");
    let outs = b.take_outcomes();
    assert_eq!(outs.len(), 1);
    assert_eq!((outs[0].tag, outs[0].finish), (0, FinishReason::Cancelled));
    assert!(outs[0].tokens.is_empty(),
            "a mid-prefill session has generated nothing");
    assert_eq!(engine.free_slots(), free0 - 1,
               "the cancelled session's pinned slot must be back");
    assert_eq!(engine.metrics.cancelled, 1);

    let rest = b.run_to_completion(&mut engine).unwrap();
    assert_eq!(rest.len(), 1);
    assert_eq!(rest[0].tag, 1);
    assert!(rest[0].finish.is_natural());
    assert_eq!(engine.free_slots(), free0, "all slots returned");
}

#[test]
fn server_cancel_during_chunked_prefill_releases_reservation() {
    // Server-level leak pin under chunking: with a one-request byte
    // budget and a tight chunk, cancelling a long-prompt request drains
    // its worst-case reservation whether the cancel lands while waiting,
    // mid-prefill, or mid-decode — and the freed budget admits a
    // follow-up request.  (The deterministic mid-prefill point is pinned
    // race-free by `cancel_mid_prefill_releases_slot_and_partial_state`;
    // here the shard thread runs concurrently.)
    let mut cfg = sim_config(1);
    cfg.scheduler.prefill_chunk = 1;
    let layout = zipcache::runtime::load_model_info("sim", "micro")
        .unwrap()
        .cache_layout();
    cfg.memory.budget_bytes =
        worst_case_resident_bytes(layout, layout.seq, cfg.quant.recompress_every);
    let server = Server::start(cfg).unwrap();
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0]);

    let long: Vec<u16> =
        TaskGen::new(Task::Lines(8), 56).sample(7).prompt().to_vec();
    let h = server
        .handle
        .submit_request(
            GenerationRequest::new(long.clone(), 4)
                .priority(Priority::Background),
        )
        .unwrap();
    h.cancel();
    let out = h.wait().unwrap();
    assert!(matches!(out.finish,
                     FinishReason::Cancelled | FinishReason::Eos
                     | FinishReason::MaxTokens));
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0],
               "reservation must drain with the cancelled request");

    // The freed budget admits (and completes) a follow-up request.
    let out = server.handle.generate(long, 2).unwrap();
    assert!(!out.tokens.is_empty());
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0]);
    server.shutdown().unwrap();
}
