//! Runtime integration tests: load the micro artifacts and verify the
//! AOT round-trip numerics — HLO text -> PJRT compile -> execute — plus the
//! Rust quantizer's agreement with the AOT quant kernel.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use zipcache::kvcache::{CompressedKV, PrecisionClass, QuantSpec};
use zipcache::runtime::{Runtime, Tensor};
use zipcache::workload::{Task, TaskGen};

fn runtime() -> Option<Runtime> {
    let dir = std::env::var("ZIPCACHE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match Runtime::load(&dir, "micro") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (artifacts not built?): {e}");
            None
        }
    }
}

fn prefill_inputs(rt: &Runtime, seed: u64) -> (Vec<i32>, Vec<f32>, usize,
                                               zipcache::workload::Sample) {
    let info = rt.model_info();
    let smax = info.max_seq;
    let gen = TaskGen::new(Task::Gsm, smax - 2);
    let sample = gen.sample(seed);
    let n = sample.prompt_len;
    let mut tokens = vec![0i32; smax];
    for (i, &t) in sample.prompt().iter().enumerate() {
        tokens[i] = t as i32;
    }
    let mut valid = vec![0f32; smax];
    valid[..n].fill(1.0);
    (tokens, valid, n, sample)
}

#[test]
fn prefill_outputs_have_expected_shapes() {
    let Some(rt) = runtime() else { return };
    let info = rt.model_info().clone();
    let smax = info.max_seq;
    let (tokens, valid, _, _) = prefill_inputs(&rt, 3);
    let out = rt.execute(&rt.entry("prefill_full"),
                         &[Tensor::i32(tokens, &[smax]),
                           Tensor::f32(valid, &[smax])]).unwrap();
    assert_eq!(out.len(), 5);
    assert_eq!(out[0].dims(), &[smax, info.vocab]); // logits
    assert_eq!(out[1].dims(), &[info.n_layers, info.n_heads, smax, info.d_head]);
    assert_eq!(out[3].dims(), &[info.n_layers, smax]); // acc saliency
    // all outputs finite
    for t in &out {
        assert!(t.as_f32().iter().all(|x| x.is_finite()));
    }
}

#[test]
fn flash_and_full_prefill_agree_on_valid_region() {
    let Some(rt) = runtime() else { return };
    let info = rt.model_info().clone();
    let (smax, pc) = (info.max_seq, info.probe_count);
    let (tokens, valid, n, _) = prefill_inputs(&rt, 7);
    let full = rt.execute(&rt.entry("prefill_full"),
                          &[Tensor::i32(tokens.clone(), &[smax]),
                            Tensor::f32(valid.clone(), &[smax])]).unwrap();
    let pidx: Vec<i32> = (0..pc as i32).map(|i| (n as i32 - 1 - i).max(0)).rev()
        .collect();
    let flash = rt.execute(&rt.entry("prefill_flash"),
                           &[Tensor::i32(tokens, &[smax]),
                             Tensor::f32(valid, &[smax]),
                             Tensor::i32(pidx, &[pc])]).unwrap();
    let (lf, lz) = (full[0].as_f32(), flash[0].as_f32());
    for i in 0..n * info.vocab {
        assert!((lf[i] - lz[i]).abs() < 3e-3,
                "logit {} differs: {} vs {}", i, lf[i], lz[i]);
    }
    // caches agree on live rows
    let (kf, kz) = (full[1].as_f32(), flash[1].as_f32());
    for hi in 0..info.n_layers * info.n_heads {
        let base = hi * smax * info.d_head;
        for j in 0..n * info.d_head {
            assert!((kf[base + j] - kz[base + j]).abs() < 1e-3);
        }
    }
}

#[test]
fn decode_matches_extended_prefill() {
    let Some(rt) = runtime() else { return };
    let info = rt.model_info().clone();
    let smax = info.max_seq;
    let (tokens, valid, n, sample) = prefill_inputs(&rt, 11);
    let full = rt.execute(&rt.entry("prefill_full"),
                          &[Tensor::i32(tokens.clone(), &[smax]),
                            Tensor::f32(valid.clone(), &[smax])]).unwrap();
    let next = sample.prompt()[2];
    let dims = [info.n_layers, info.n_heads, smax, info.d_head];
    let dec = rt.execute(&rt.entry("decode"), &[
        Tensor::scalar_i32(next as i32),
        Tensor::scalar_i32(n as i32),
        Tensor::f32(full[1].as_f32().to_vec(), &dims),
        Tensor::f32(full[2].as_f32().to_vec(), &dims),
        Tensor::f32(valid.clone(), &[smax]),
    ]).unwrap();
    // extended prefill reference
    let mut tokens2 = tokens.clone();
    tokens2[n] = next as i32;
    let mut valid2 = valid.clone();
    valid2[n] = 1.0;
    let full2 = rt.execute(&rt.entry("prefill_full"),
                           &[Tensor::i32(tokens2, &[smax]),
                             Tensor::f32(valid2, &[smax])]).unwrap();
    let want = &full2[0].as_f32()[n * info.vocab..(n + 1) * info.vocab];
    let got = dec[0].as_f32();
    for i in 0..info.vocab {
        assert!((got[i] - want[i]).abs() < 5e-3,
                "logit {i}: {} vs {}", got[i], want[i]);
    }
    // a_row is a probability row over cached tokens
    let a = dec[3].as_f32();
    assert!(a.iter().all(|&x| (0.0..=1.0).contains(&x) && x.is_finite()));
}

#[test]
fn rust_quant_matches_aot_quant_kernel() {
    let Some(rt) = runtime() else { return };
    let info = rt.model_info().clone();
    let layout = info.cache_layout();
    let smax = info.max_seq;
    let (tokens, valid, n, _) = prefill_inputs(&rt, 13);
    let full = rt.execute(&rt.entry("prefill_full"),
                          &[Tensor::i32(tokens, &[smax]),
                            Tensor::f32(valid.clone(), &[smax])]).unwrap();
    let kc = full[1].as_f32().to_vec();
    let vc = full[2].as_f32().to_vec();

    // salient mask: every 3rd token
    let mut sal = vec![0f32; smax];
    for i in (0..n).step_by(3) {
        sal[i] = 1.0;
    }
    let dims = [info.n_layers, info.n_heads, smax, info.d_head];
    let aot = rt.execute(&rt.entry("quant_kv"), &[
        Tensor::f32(kc.clone(), &dims),
        Tensor::f32(vc.clone(), &dims),
        Tensor::f32(sal.clone(), &[smax]),
    ]).unwrap();

    // Rust store with the same classes (hi=4/lo=2, channel-K/CST-V).
    // NOTE: the AOT kernel quantizes each full plane with one parameter set
    // and selects per token, while the Rust store quantizes the salient and
    // regular subsets on their own statistics (Alg. 2's Split).  They agree
    // exactly on the hi/lo *shared-stats* case only when the subsets span
    // the full plane; here we verify agreement in distribution: per-token
    // errors of the Rust path must not exceed the AOT fake-quant's.
    let classes: Vec<PrecisionClass> = (0..n)
        .map(|i| PrecisionClass::Bits(if i % 3 == 0 { 4 } else { 2 }))
        .collect();
    let store = CompressedKV::compress(&kc, &vc, layout, &classes,
                                       QuantSpec::default());
    let mut ko = vec![0f32; layout.cache_len()];
    let mut vo = vec![0f32; layout.cache_len()];
    let mut va = vec![0f32; smax];
    store.materialize_into(&mut ko, &mut vo, &mut va);

    let err = |a: &[f32], b: &[f32]| -> f64 {
        let mut e = 0f64;
        let mut cnt = 0usize;
        for hi in 0..layout.layers * layout.heads {
            let base = hi * smax * layout.d_head;
            for t in 0..n {
                for j in 0..layout.d_head {
                    let idx = base + t * layout.d_head + j;
                    e += ((a[idx] - b[idx]) as f64).powi(2);
                    cnt += 1;
                }
            }
        }
        e / cnt as f64
    };
    let aot_kerr = err(aot[0].as_f32(), &kc);
    let rust_kerr = err(&ko, &kc);
    let aot_verr = err(aot[1].as_f32(), &vc);
    let rust_verr = err(&vo, &vc);
    // Subset statistics can only tighten ranges -> Rust error <= ~AOT error.
    assert!(rust_kerr <= aot_kerr * 1.2 + 1e-9, "{rust_kerr} vs {aot_kerr}");
    assert!(rust_verr <= aot_verr * 1.2 + 1e-9, "{rust_verr} vs {aot_verr}");
}

#[test]
fn unknown_model_rejected() {
    let dir = std::env::var("ZIPCACHE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        return;
    }
    assert!(Runtime::load(&dir, "bogus-model").is_err());
}
