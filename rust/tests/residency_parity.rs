//! Compressed-resident session contracts (DESIGN.md §10), all runnable
//! with no artifacts on the sim backend:
//!
//! * **Slot-count determinism** — per-tag outputs are bit-identical for
//!   `memory.slots` ∈ {1, 2, max_batch} and identical to a bare engine
//!   run (same digest discipline as `parallel_parity.rs`): park/unpark
//!   reconstructs dense state exactly, so bounding residency never
//!   perturbs generation.
//! * **Park round trip** — parking and unparking a mid-flight session
//!   restores its dense buffers and retained compressed snapshot
//!   bitwise.
//! * **Budget boundary** — the worst-case byte budget rejects at submit
//!   time, mirrors the `queue_depth` boundary discipline, and drains its
//!   reservations as requests complete.

use zipcache::config::EngineConfig;
use zipcache::coordinator::batcher::{ContinuousBatcher, LruByLastStep, QueuedRequest};
use zipcache::coordinator::{CancelToken, Engine, FinishReason, GenerationRequest};
use zipcache::kvcache::worst_case_resident_bytes;
use zipcache::server::{loadgen, Server};
use zipcache::workload::{Task, TaskGen};

const MAX_BATCH: usize = 4;
const MAX_NEW: usize = 8;

fn sim_config(slots: usize) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").unwrap();
    cfg.scheduler.max_batch = MAX_BATCH;
    cfg.memory.slots = slots; // 0 = one slot per decode slot
    cfg.quant.recompress_every = 4; // several streaming cycles per request
    cfg.parallelism = 1;
    cfg
}

fn prompts(n: usize) -> Vec<Vec<u16>> {
    let gen = TaskGen::new(Task::Code, 50);
    (0..n).map(|i| gen.sample(i as u64).prompt().to_vec()).collect()
}

type Outcome = (u64, Vec<u16>, usize, f64);

/// Run the prompt set through a batcher bounded to `slots` dense slots;
/// returns per-tag outcomes plus (preempted, peak slots in use).
fn run_batched(slots: usize, lru: bool) -> (Vec<Outcome>, u64, usize) {
    let mut engine = Engine::new(sim_config(slots)).unwrap();
    let mut b = if lru {
        ContinuousBatcher::with_policy(MAX_BATCH, 16, Box::new(LruByLastStep))
    } else {
        ContinuousBatcher::new(MAX_BATCH, 16)
    };
    for (tag, p) in prompts(8).into_iter().enumerate() {
        b.submit(QueuedRequest {
            request: GenerationRequest::new(p, MAX_NEW),
            tag: tag as u64,
        })
        .unwrap();
    }
    let outcomes = b
        .run_to_completion(&mut engine)
        .unwrap()
        .into_iter()
        .map(|o| (o.tag, o.tokens, o.cache_bytes, o.compression_ratio))
        .collect();
    (outcomes, b.preempted(), engine.slot_pool().peak_in_use())
}

#[test]
fn outputs_identical_across_slot_counts_and_vs_bare_engine() {
    // Bare engine, sequential — the unbatched ground truth.
    let mut engine = Engine::new(sim_config(0)).unwrap();
    let bare: Vec<Outcome> = prompts(8)
        .iter()
        .enumerate()
        .map(|(tag, p)| {
            let o = engine.generate(p, MAX_NEW).unwrap();
            (tag as u64, o.tokens, o.cache_bytes, o.compression_ratio)
        })
        .collect();
    assert!(bare.iter().all(|(_, t, _, _)| !t.is_empty()));

    let (full, preempted_full, peak_full) = run_batched(0, false);
    assert_eq!(full, bare, "slots == max_batch changed outputs vs bare engine");
    assert_eq!(preempted_full, 0, "full slot pool must never park");
    assert!(peak_full <= MAX_BATCH);

    for slots in [1usize, 2] {
        let (out, preempted, peak) = run_batched(slots, false);
        assert_eq!(out, bare, "slots={slots} changed per-request outputs");
        assert!(preempted > 0, "slots={slots} never parked a session");
        assert!(peak <= slots, "slots={slots}: {peak} dense slots in use");
    }

    // The LRU park policy schedules differently but must not change
    // outputs either (park/unpark is bit-exact, sessions independent).
    let (lru, lru_preempted, _) = run_batched(1, true);
    assert_eq!(lru, bare, "LRU park policy changed outputs");
    assert!(lru_preempted > 0);
}

#[test]
fn park_unpark_roundtrip_is_bitwise() {
    let mut cfg = sim_config(0);
    cfg.scheduler.max_batch = 2; // pool of two slots
    cfg.quant.recompress_every = 8;
    let mut engine = Engine::new(cfg).unwrap();
    let p = prompts(1).remove(0);
    // Two sessions with identical content follow identical trajectories
    // (content-derived seeds); `b` is the never-parked control.
    let mut a = engine
        .start_session(GenerationRequest::new(p.clone(), 12))
        .unwrap();
    let mut b = engine
        .start_session(GenerationRequest::new(p, 12))
        .unwrap();
    for _ in 0..5 {
        engine.decode_step(&mut a).unwrap();
        engine.decode_step(&mut b).unwrap();
    }

    let k0 = a.kbuf().to_vec();
    let v0 = a.vbuf().to_vec();
    let m0 = a.slot().valid.clone();
    let d0 = a.compressed.as_ref().unwrap().content_digest();

    engine.park(&mut a);
    assert!(a.is_parked());
    assert_eq!(engine.free_slots(), 1, "parking must return the slot");
    assert_eq!(engine.metrics.park_cycles, 1);
    assert!(engine.decode_step(&mut a).is_err(),
            "decoding a parked session must fail loudly");
    // Parked resident footprint excludes the dense slot entirely.
    assert!(a.resident_bytes() < engine.slot_pool().slot_bytes());

    engine.unpark(&mut a).unwrap();
    assert_eq!(a.kbuf(), &k0[..], "K cache not restored bitwise");
    assert_eq!(a.vbuf(), &v0[..], "V cache not restored bitwise");
    assert_eq!(a.slot().valid, m0, "validity mask not restored bitwise");
    assert_eq!(a.compressed.as_ref().unwrap().content_digest(), d0,
               "retained snapshot changed across park/unpark");

    // Second round trip (recycled, re-zeroed slot) is just as exact.
    engine.park(&mut a);
    engine.unpark(&mut a).unwrap();
    assert_eq!(a.kbuf(), &k0[..]);
    assert_eq!(a.vbuf(), &v0[..]);

    // Both sessions finish with identical tokens.
    while !a.is_done() {
        engine.decode_step(&mut a).unwrap();
    }
    while !b.is_done() {
        engine.decode_step(&mut b).unwrap();
    }
    assert_eq!(a.generated, b.generated,
               "park/unpark round trips changed generated tokens");
    engine.finish(a);
    engine.finish(b);
    assert_eq!(engine.free_slots(), 2, "finish must release every slot");
}

#[test]
fn slot_pool_exhaustion_is_an_error_not_a_hang() {
    let mut cfg = sim_config(1);
    cfg.scheduler.max_batch = 2;
    let mut engine = Engine::new(cfg).unwrap();
    let mut ps = prompts(2);
    let s = engine
        .start_session(GenerationRequest::new(ps.remove(0), 4))
        .unwrap();
    let err = engine
        .start_session(GenerationRequest::new(ps.remove(0), 4))
        .unwrap_err();
    assert!(err.to_string().contains("materialization slot"), "{err}");
    engine.finish(s);
    // Slot released: a new session starts cleanly.
    let s = engine
        .start_session(GenerationRequest::new(prompts(1).remove(0), 4))
        .unwrap();
    engine.finish(s);
}

#[test]
fn session_cache_bytes_stay_under_worst_case_bound() {
    // The admission bound must actually dominate what sessions hold —
    // otherwise the budget boundary is a fiction.
    let cfg = sim_config(0);
    let recompress = cfg.quant.recompress_every;
    let mut engine = Engine::new(cfg).unwrap();
    let layout = engine.layout();
    for p in prompts(4) {
        let n = p.len() + MAX_NEW;
        let out = engine.generate(&p, MAX_NEW).unwrap();
        assert!(
            out.cache_bytes <= worst_case_resident_bytes(layout, n, recompress),
            "cache_bytes {} exceeds worst-case bound {}",
            out.cache_bytes,
            worst_case_resident_bytes(layout, n, recompress)
        );
    }
}

#[test]
fn budget_rejects_at_submit_time_and_drains() {
    // Budget sized to one worst-case request: back-to-back submission of
    // six requests must hit the budget boundary at submit time (mirroring
    // the queue_depth overload test), everything accepted completes, and
    // the reservations drain to zero.
    let mut cfg = sim_config(0);
    let layout = zipcache::runtime::load_model_info("sim", "micro")
        .unwrap()
        .cache_layout();
    let ps = prompts(6);
    let wc = worst_case_resident_bytes(
        layout,
        ps.iter().map(|p| p.len()).max().unwrap() + MAX_NEW,
        cfg.quant.recompress_every,
    );
    cfg.memory.budget_bytes = wc;
    let server = Server::start(cfg).unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for p in ps {
        match server.handle.submit(p, MAX_NEW) {
            Ok(h) => accepted.push(h),
            Err(e) => {
                assert!(e.to_string().contains("memory budget"), "{e}");
                rejected += 1;
            }
        }
    }
    assert!(rejected >= 1, "no budget backpressure observed");
    let completed = accepted.len();
    for h in accepted {
        h.wait().unwrap();
    }
    assert_eq!(completed + rejected, 6);
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0],
               "reservations must drain at completion");
    server.shutdown().unwrap();
}

#[test]
fn zero_budget_means_unlimited() {
    let cfg = sim_config(0); // budget_bytes = 0
    let server = Server::start(cfg).unwrap();
    let handles: Vec<_> = prompts(6)
        .into_iter()
        .map(|p| server.handle.submit(p, MAX_NEW).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0]);
    server.shutdown().unwrap();
}

#[test]
fn memory_pressure_trace_exercises_the_rejection_path() {
    // Replay the loadgen scenario against a deliberately tight budget:
    // long-window short-decode requests pin near-worst-case footprints,
    // so the admission boundary must fire under real concurrency.
    let mut cfg = sim_config(1);
    let layout = zipcache::runtime::load_model_info("sim", "micro")
        .unwrap()
        .cache_layout();
    cfg.memory.budget_bytes =
        2 * worst_case_resident_bytes(layout, layout.seq, cfg.quant.recompress_every);
    let server = Server::start(cfg).unwrap();
    let trace = loadgen::memory_pressure_trace(layout.seq, 12, 7);
    let report = loadgen::replay(&server.handle, &trace).unwrap();
    assert_eq!(report.completed + report.rejected, 12);
    assert!(report.rejected >= 1, "tight budget never rejected");
    assert!(report.completed >= 1, "budget admitted nothing");
    assert_eq!(report.failed, 0);
    // Every admitted long-window request completes with output even
    // while parked/unparked through the single slot.
    for (i, out) in &report.outputs {
        assert!(!out.tokens.is_empty(), "request {i} produced no tokens");
        assert!(out.tokens.len() <= trace.entries[*i].max_new_tokens);
    }
    let snap = server.handle.metrics();
    assert!(snap.total.peak_resident_bytes > 0);
    server.shutdown().unwrap();
}

// ---- cancellation / deadline lifecycle (DESIGN.md §11) --------------------

#[test]
fn cancel_mid_decode_releases_slot_and_counts() {
    // Deterministic (single-threaded) mid-decode cancellation through
    // the batcher: after a few iterations, fire one active session's
    // token — the batcher must retire it with FinishReason::Cancelled at
    // the next step, its DenseSlot must return to the pool, and the
    // tokens generated before the cancel must be kept.  This is the leak
    // class PR-4's Drop-based slot release was built to prevent, now on
    // the explicit cancellation path.
    let mut engine = Engine::new(sim_config(0)).unwrap();
    let free0 = engine.free_slots();
    let mut b = ContinuousBatcher::new(MAX_BATCH, 16);
    let cancel = CancelToken::new();
    let mut ps = prompts(2);
    b.submit(QueuedRequest {
        request: GenerationRequest::new(ps.remove(0), MAX_NEW)
            .cancel_token(cancel.clone()),
        tag: 0,
    })
    .unwrap();
    b.submit(QueuedRequest {
        request: GenerationRequest::new(ps.remove(0), MAX_NEW),
        tag: 1,
    })
    .unwrap();
    for _ in 0..3 {
        b.step(&mut engine).unwrap();
    }
    assert_eq!(b.active(), 2, "both sessions should still be decoding");
    cancel.cancel();
    b.step(&mut engine).unwrap();
    let cancelled: Vec<_> = b.take_outcomes();
    assert_eq!(cancelled.len(), 1, "cancel must retire exactly one session");
    assert_eq!(cancelled[0].tag, 0);
    assert_eq!(cancelled[0].finish, FinishReason::Cancelled);
    assert!(!cancelled[0].tokens.is_empty(),
            "tokens generated before the cancel are kept");
    assert_eq!(engine.free_slots(), free0 - 1,
               "cancelled session's slot must be back (only tag 1 holds one)");
    assert_eq!(engine.metrics.cancelled, 1);
    // The survivor completes untouched.
    let rest = b.run_to_completion(&mut engine).unwrap();
    assert_eq!(rest.len(), 1);
    assert_eq!(rest[0].tag, 1);
    assert_eq!(engine.free_slots(), free0, "all slots returned");
}

#[test]
fn cancel_while_waiting_never_takes_a_slot() {
    // A pre-cancelled request retires at pop time with no session: slot
    // pool untouched, counted in metrics.cancelled, empty tokens.
    let mut engine = Engine::new(sim_config(0)).unwrap();
    let free0 = engine.free_slots();
    let mut b = ContinuousBatcher::new(MAX_BATCH, 16);
    let req = GenerationRequest::new(prompts(1).remove(0), MAX_NEW);
    req.cancel.cancel();
    b.submit(QueuedRequest { request: req, tag: 9 }).unwrap();
    let report = b.step(&mut engine).unwrap();
    assert_eq!(report.activated, 1, "pop-time retirement counts as leaving \
                                     the staging queue");
    assert_eq!(b.take_departed(), 0,
               "a successful step reports all departures itself");
    let out = b.take_outcomes();
    assert_eq!(out.len(), 1);
    assert_eq!((out[0].tag, out[0].finish), (9, FinishReason::Cancelled));
    assert!(out[0].tokens.is_empty());
    assert_eq!(engine.free_slots(), free0, "no slot may be consumed");
    assert_eq!(engine.metrics.cancelled, 1);
    assert_eq!(engine.metrics.admitted_by_priority, [0, 0, 0]);
}

#[test]
fn expired_deadline_sheds_at_pop_without_a_slot() {
    let mut engine = Engine::new(sim_config(0)).unwrap();
    let free0 = engine.free_slots();
    let mut b = ContinuousBatcher::new(MAX_BATCH, 16);
    let mut ps = prompts(2);
    b.submit(QueuedRequest {
        request: GenerationRequest::new(ps.remove(0), MAX_NEW)
            .deadline_in(std::time::Duration::ZERO),
        tag: 0,
    })
    .unwrap();
    b.submit(QueuedRequest {
        request: GenerationRequest::new(ps.remove(0), MAX_NEW),
        tag: 1,
    })
    .unwrap();
    let outcomes = b.run_to_completion(&mut engine).unwrap();
    assert_eq!(outcomes.len(), 2);
    assert_eq!(outcomes[0].finish, FinishReason::DeadlineExpired);
    assert!(outcomes[0].tokens.is_empty());
    assert!(matches!(outcomes[1].finish,
                     FinishReason::Eos | FinishReason::MaxTokens));
    assert!(!outcomes[1].tokens.is_empty());
    assert_eq!(engine.free_slots(), free0);
    assert_eq!(engine.metrics.shed_by_priority, [1, 0, 0]);
    assert_eq!(engine.metrics.cancelled, 0);
}

#[test]
fn server_cancellation_releases_reservation_immediately() {
    // The server-level leak pin: with a byte budget configured, a
    // cancelled request's worst-case reservation and slot must be gone by
    // the time its final response is observable — pre-submit values
    // restored — and the freed budget must admit a follow-up request.
    let mut cfg = sim_config(0);
    let layout = zipcache::runtime::load_model_info("sim", "micro")
        .unwrap()
        .cache_layout();
    let wc = worst_case_resident_bytes(layout, layout.seq,
                                       cfg.quant.recompress_every);
    cfg.memory.budget_bytes = wc; // exactly one worst-case request fits
    let server = Server::start(cfg).unwrap();
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0]);

    // Mid-decode cancel, synchronized through the token stream: after
    // the first streamed token the session provably holds a slot.
    // (No mid-flight reserved>0 assert here: the shard thread runs
    // concurrently and could complete the whole request first — the
    // reservation-while-in-flight boundary is pinned race-free by the
    // dispatcher unit tests and budget_rejects_at_submit_time.)
    let mut h = server
        .handle
        .submit_request(GenerationRequest::new(prompts(1).remove(0), MAX_NEW))
        .unwrap();
    let first = h.next_token();
    assert!(first.is_some(), "no streamed token before completion");
    h.cancel();
    let out = h.wait().unwrap();
    // The reservation is released before the reply is delivered
    // (DESIGN.md §11): observable as already-zero here.
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0],
               "reservation must be released at cancellation, not later");
    assert_eq!(out.tokens.first().copied(), first,
               "stream prefix must match the final tokens");
    // Race-free assertions only: the session may have finished naturally
    // just before the cancel landed; either way nothing may leak.
    assert!(matches!(out.finish, FinishReason::Cancelled
                     | FinishReason::Eos | FinishReason::MaxTokens));

    // Deterministic cancelled-reason path: a pre-cancelled token.
    let cancel = CancelToken::new();
    cancel.cancel();
    let out = server
        .handle
        .submit_request(
            GenerationRequest::new(prompts(1).remove(0), MAX_NEW)
                .cancel_token(cancel),
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out.finish, FinishReason::Cancelled);
    assert!(out.tokens.is_empty());
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0]);

    // The freed budget admits a fresh worst-case request end to end.
    let out = server
        .handle
        .generate(prompts(1).remove(0), MAX_NEW)
        .unwrap();
    assert!(!out.tokens.is_empty());
    let snap = server.handle.metrics();
    assert!(snap.total.cancelled >= 1);
    server.shutdown().unwrap();
}

#[test]
fn priority_mix_trace_exercises_cancel_and_shed_counters() {
    // The CI smoke scenario (DESIGN.md §11): a mixed-priority trace with
    // one pre-cancelled and one deadline-shed request.  Replay must
    // resolve every submission, and the per-priority / finish-reason
    // counters in MetricsSnapshot must record the mix.
    let mut cfg = sim_config(0);
    cfg.scheduler.shards = 2;
    let layout = zipcache::runtime::load_model_info("sim", "micro")
        .unwrap()
        .cache_layout();
    let server = Server::start(cfg).unwrap();
    let n = 8;
    let trace = loadgen::priority_mix_trace(layout.seq, n, 4, 11);
    let report = loadgen::replay(&server.handle, &trace).unwrap();
    assert_eq!(report.completed + report.rejected + report.cancelled
                   + report.shed,
               n);
    assert_eq!(report.rejected, 0, "default queue depth must admit all");
    assert_eq!(report.cancelled, 1, "exactly one pre-cancelled entry");
    assert_eq!(report.shed, 1, "exactly one expired-deadline entry");
    assert_eq!(report.failed, 0);
    for (i, out) in &report.outputs {
        match out.finish {
            FinishReason::Cancelled => assert!(trace.entries[*i].cancelled),
            FinishReason::DeadlineExpired => {
                assert_eq!(trace.entries[*i].deadline_ms, Some(0.0))
            }
            _ => assert!(!out.tokens.is_empty()),
        }
    }
    let snap = server.handle.metrics();
    assert_eq!(snap.total.cancelled, 1);
    assert_eq!(snap.total.shed_by_priority.iter().sum::<u64>(), 1);
    assert_eq!(snap.total.completed_by_priority.iter().sum::<u64>(),
               report.completed as u64);
    // All three classes saw admissions (n = 8 cycles interactive, batch,
    // background; the two special entries are the last two tags).
    assert_eq!(snap.total.admitted_by_priority.iter().sum::<u64>(),
               report.completed as u64);
    assert!(snap.total.admitted_by_priority.iter().all(|&c| c >= 1),
            "every priority class must see traffic: {:?}",
            snap.total.admitted_by_priority);
    // Per-shard counters sum to the totals (aggregation contract).
    let by_shard: u64 = snap
        .per_shard
        .iter()
        .map(|m| m.completed_by_priority.iter().sum::<u64>())
        .sum();
    assert_eq!(by_shard, report.completed as u64);
    server.shutdown().unwrap();
}
