//! Compressed-resident session contracts (DESIGN.md §10), all runnable
//! with no artifacts on the sim backend:
//!
//! * **Slot-count determinism** — per-tag outputs are bit-identical for
//!   `memory.slots` ∈ {1, 2, max_batch} and identical to a bare engine
//!   run (same digest discipline as `parallel_parity.rs`): park/unpark
//!   reconstructs dense state exactly, so bounding residency never
//!   perturbs generation.
//! * **Park round trip** — parking and unparking a mid-flight session
//!   restores its dense buffers and retained compressed snapshot
//!   bitwise.
//! * **Budget boundary** — the worst-case byte budget rejects at submit
//!   time, mirrors the `queue_depth` boundary discipline, and drains its
//!   reservations as requests complete.

use zipcache::config::EngineConfig;
use zipcache::coordinator::batcher::{ContinuousBatcher, LruByLastStep, QueuedRequest};
use zipcache::coordinator::Engine;
use zipcache::kvcache::worst_case_resident_bytes;
use zipcache::server::{loadgen, Server};
use zipcache::workload::{Task, TaskGen};

const MAX_BATCH: usize = 4;
const MAX_NEW: usize = 8;

fn sim_config(slots: usize) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").unwrap();
    cfg.scheduler.max_batch = MAX_BATCH;
    cfg.memory.slots = slots; // 0 = one slot per decode slot
    cfg.quant.recompress_every = 4; // several streaming cycles per request
    cfg.parallelism = 1;
    cfg
}

fn prompts(n: usize) -> Vec<Vec<u16>> {
    let gen = TaskGen::new(Task::Code, 50);
    (0..n).map(|i| gen.sample(i as u64).prompt().to_vec()).collect()
}

type Outcome = (u64, Vec<u16>, usize, f64);

/// Run the prompt set through a batcher bounded to `slots` dense slots;
/// returns per-tag outcomes plus (preempted, peak slots in use).
fn run_batched(slots: usize, lru: bool) -> (Vec<Outcome>, u64, usize) {
    let mut engine = Engine::new(sim_config(slots)).unwrap();
    let mut b = if lru {
        ContinuousBatcher::with_policy(MAX_BATCH, 16, Box::new(LruByLastStep))
    } else {
        ContinuousBatcher::new(MAX_BATCH, 16)
    };
    for (tag, p) in prompts(8).into_iter().enumerate() {
        b.submit(QueuedRequest { prompt: p, max_new: MAX_NEW, tag: tag as u64 })
            .unwrap();
    }
    let outcomes = b
        .run_to_completion(&mut engine)
        .unwrap()
        .into_iter()
        .map(|o| (o.tag, o.output.tokens, o.output.cache_bytes,
                  o.output.compression_ratio))
        .collect();
    (outcomes, b.preempted(), engine.slot_pool().peak_in_use())
}

#[test]
fn outputs_identical_across_slot_counts_and_vs_bare_engine() {
    // Bare engine, sequential — the unbatched ground truth.
    let mut engine = Engine::new(sim_config(0)).unwrap();
    let bare: Vec<Outcome> = prompts(8)
        .iter()
        .enumerate()
        .map(|(tag, p)| {
            let o = engine.generate(p, MAX_NEW).unwrap();
            (tag as u64, o.tokens, o.cache_bytes, o.compression_ratio)
        })
        .collect();
    assert!(bare.iter().all(|(_, t, _, _)| !t.is_empty()));

    let (full, preempted_full, peak_full) = run_batched(0, false);
    assert_eq!(full, bare, "slots == max_batch changed outputs vs bare engine");
    assert_eq!(preempted_full, 0, "full slot pool must never park");
    assert!(peak_full <= MAX_BATCH);

    for slots in [1usize, 2] {
        let (out, preempted, peak) = run_batched(slots, false);
        assert_eq!(out, bare, "slots={slots} changed per-request outputs");
        assert!(preempted > 0, "slots={slots} never parked a session");
        assert!(peak <= slots, "slots={slots}: {peak} dense slots in use");
    }

    // The LRU park policy schedules differently but must not change
    // outputs either (park/unpark is bit-exact, sessions independent).
    let (lru, lru_preempted, _) = run_batched(1, true);
    assert_eq!(lru, bare, "LRU park policy changed outputs");
    assert!(lru_preempted > 0);
}

#[test]
fn park_unpark_roundtrip_is_bitwise() {
    let mut cfg = sim_config(0);
    cfg.scheduler.max_batch = 2; // pool of two slots
    cfg.quant.recompress_every = 8;
    let mut engine = Engine::new(cfg).unwrap();
    let p = prompts(1).remove(0);
    // Two sessions with identical content follow identical trajectories
    // (content-derived seeds); `b` is the never-parked control.
    let mut a = engine.start_session(p.clone(), 12).unwrap();
    let mut b = engine.start_session(p, 12).unwrap();
    for _ in 0..5 {
        engine.decode_step(&mut a).unwrap();
        engine.decode_step(&mut b).unwrap();
    }

    let k0 = a.kbuf().to_vec();
    let v0 = a.vbuf().to_vec();
    let m0 = a.slot().valid.clone();
    let d0 = a.compressed.as_ref().unwrap().content_digest();

    engine.park(&mut a);
    assert!(a.is_parked());
    assert_eq!(engine.free_slots(), 1, "parking must return the slot");
    assert_eq!(engine.metrics.park_cycles, 1);
    assert!(engine.decode_step(&mut a).is_err(),
            "decoding a parked session must fail loudly");
    // Parked resident footprint excludes the dense slot entirely.
    assert!(a.resident_bytes() < engine.slot_pool().slot_bytes());

    engine.unpark(&mut a).unwrap();
    assert_eq!(a.kbuf(), &k0[..], "K cache not restored bitwise");
    assert_eq!(a.vbuf(), &v0[..], "V cache not restored bitwise");
    assert_eq!(a.slot().valid, m0, "validity mask not restored bitwise");
    assert_eq!(a.compressed.as_ref().unwrap().content_digest(), d0,
               "retained snapshot changed across park/unpark");

    // Second round trip (recycled, re-zeroed slot) is just as exact.
    engine.park(&mut a);
    engine.unpark(&mut a).unwrap();
    assert_eq!(a.kbuf(), &k0[..]);
    assert_eq!(a.vbuf(), &v0[..]);

    // Both sessions finish with identical tokens.
    while !a.is_done() {
        engine.decode_step(&mut a).unwrap();
    }
    while !b.is_done() {
        engine.decode_step(&mut b).unwrap();
    }
    assert_eq!(a.generated, b.generated,
               "park/unpark round trips changed generated tokens");
    engine.finish(a);
    engine.finish(b);
    assert_eq!(engine.free_slots(), 2, "finish must release every slot");
}

#[test]
fn slot_pool_exhaustion_is_an_error_not_a_hang() {
    let mut cfg = sim_config(1);
    cfg.scheduler.max_batch = 2;
    let mut engine = Engine::new(cfg).unwrap();
    let mut ps = prompts(2);
    let s = engine.start_session(ps.remove(0), 4).unwrap();
    let err = engine.start_session(ps.remove(0), 4).unwrap_err();
    assert!(err.to_string().contains("materialization slot"), "{err}");
    engine.finish(s);
    // Slot released: a new session starts cleanly.
    let s = engine.start_session(prompts(1).remove(0), 4).unwrap();
    engine.finish(s);
}

#[test]
fn session_cache_bytes_stay_under_worst_case_bound() {
    // The admission bound must actually dominate what sessions hold —
    // otherwise the budget boundary is a fiction.
    let cfg = sim_config(0);
    let recompress = cfg.quant.recompress_every;
    let mut engine = Engine::new(cfg).unwrap();
    let layout = engine.layout();
    for p in prompts(4) {
        let n = p.len() + MAX_NEW;
        let out = engine.generate(&p, MAX_NEW).unwrap();
        assert!(
            out.cache_bytes <= worst_case_resident_bytes(layout, n, recompress),
            "cache_bytes {} exceeds worst-case bound {}",
            out.cache_bytes,
            worst_case_resident_bytes(layout, n, recompress)
        );
    }
}

#[test]
fn budget_rejects_at_submit_time_and_drains() {
    // Budget sized to one worst-case request: back-to-back submission of
    // six requests must hit the budget boundary at submit time (mirroring
    // the queue_depth overload test), everything accepted completes, and
    // the reservations drain to zero.
    let mut cfg = sim_config(0);
    let layout = zipcache::runtime::load_model_info("sim", "micro")
        .unwrap()
        .cache_layout();
    let ps = prompts(6);
    let wc = worst_case_resident_bytes(
        layout,
        ps.iter().map(|p| p.len()).max().unwrap() + MAX_NEW,
        cfg.quant.recompress_every,
    );
    cfg.memory.budget_bytes = wc;
    let server = Server::start(cfg).unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for p in ps {
        match server.handle.submit(p, MAX_NEW) {
            Ok(h) => accepted.push(h),
            Err(e) => {
                assert!(e.to_string().contains("memory budget"), "{e}");
                rejected += 1;
            }
        }
    }
    assert!(rejected >= 1, "no budget backpressure observed");
    let completed = accepted.len();
    for h in accepted {
        h.wait().unwrap();
    }
    assert_eq!(completed + rejected, 6);
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0],
               "reservations must drain at completion");
    server.shutdown().unwrap();
}

#[test]
fn zero_budget_means_unlimited() {
    let cfg = sim_config(0); // budget_bytes = 0
    let server = Server::start(cfg).unwrap();
    let handles: Vec<_> = prompts(6)
        .into_iter()
        .map(|p| server.handle.submit(p, MAX_NEW).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    assert_eq!(server.handle.shard_reserved_bytes(), vec![0]);
    server.shutdown().unwrap();
}

#[test]
fn memory_pressure_trace_exercises_the_rejection_path() {
    // Replay the loadgen scenario against a deliberately tight budget:
    // long-window short-decode requests pin near-worst-case footprints,
    // so the admission boundary must fire under real concurrency.
    let mut cfg = sim_config(1);
    let layout = zipcache::runtime::load_model_info("sim", "micro")
        .unwrap()
        .cache_layout();
    cfg.memory.budget_bytes =
        2 * worst_case_resident_bytes(layout, layout.seq, cfg.quant.recompress_every);
    let server = Server::start(cfg).unwrap();
    let trace = loadgen::memory_pressure_trace(layout.seq, 12, 7);
    let report = loadgen::replay(&server.handle, &trace).unwrap();
    assert_eq!(report.completed + report.rejected, 12);
    assert!(report.rejected >= 1, "tight budget never rejected");
    assert!(report.completed >= 1, "budget admitted nothing");
    assert_eq!(report.failed, 0);
    // Every admitted long-window request completes with output even
    // while parked/unparked through the single slot.
    for (i, out) in &report.outputs {
        assert!(!out.tokens.is_empty(), "request {i} produced no tokens");
        assert!(out.tokens.len() <= trace.entries[*i].max_new_tokens);
    }
    let snap = server.handle.metrics();
    assert!(snap.total.peak_resident_bytes > 0);
    server.shutdown().unwrap();
}
