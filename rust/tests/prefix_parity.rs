//! Shared-prefix segment-store parity contracts (DESIGN.md §16), all
//! runnable with no artifacts on the sim backend:
//!
//! * **Warm == cold, bitwise** — a fork-from-prefix session (segments
//!   materialized, saliency catch-up, suffix-only prefill) generates the
//!   same tokens and retains the same snapshot `content_digest` as a
//!   cold start, across prefill chunk {0, 3} × quant kernel
//!   {scalar, auto} × policy {zipcache, h2o}, and through the sharded
//!   server across shards {1, 2} × slots {1, 2, max}.
//! * **Accounting** — `resident_bytes` of a warm session equals the
//!   cold session's (shared segments are counted once per shard, never
//!   per session), and `prefill_tokens_skipped` matches the granule
//!   boundary rule exactly.
//! * **Lifecycle** — dropping a session mid-prefill on the hit path
//!   releases every segment pin; LRU churn under a tight
//!   `prefix.max_bytes` evicts without leaking (all store gauges drain
//!   to zero once sessions are gone).

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::kvcache::prefix_store::DEFAULT_GRANULE;
use zipcache::quant::KernelChoice;
use zipcache::server::{loadgen, Server};

const MAX_NEW: usize = 6;

fn cfg_with(chunk: usize, prefix: bool) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").unwrap();
    cfg.scheduler.prefill_chunk = chunk;
    cfg.quant.recompress_every = 4; // several streaming cycles per request
    cfg.parallelism = 1;
    cfg.prefix.enable = prefix;
    cfg
}

/// One shared-prefix phase: three prompts over one long system prompt
/// with distinct 3-token tails (micro window = 64).
fn prompts() -> Vec<Vec<u16>> {
    loadgen::shared_prefix_trace(64, 3, 0, 11)
        .entries
        .iter()
        .map(|e| e.sample.prompt().to_vec())
        .collect()
}

/// Drive one prompt to completion on `engine`; returns the generated
/// tokens and the retained snapshot's content digest.  The session drop
/// returns the dense slot and releases any segment pins.
fn run_one(engine: &mut Engine, p: &[u16]) -> (Vec<u16>, u64) {
    let mut s = engine
        .start_session(GenerationRequest::new(p.to_vec(), MAX_NEW))
        .unwrap();
    while !s.is_done() {
        engine.decode_step(&mut s).unwrap();
    }
    let digest = s.compressed.as_ref().unwrap().content_digest();
    (s.generated.clone(), digest)
}

/// Cold ground truth: a fresh prefix-disabled engine per prompt.
fn cold_run(chunk: usize, policy: PolicyKind, kernel: KernelChoice,
            p: &[u16]) -> (Vec<u16>, u64) {
    let mut cfg = cfg_with(chunk, false);
    cfg.policy = policy;
    cfg.quant.kernel = kernel;
    run_one(&mut Engine::new(cfg).unwrap(), p)
}

#[test]
fn warm_fork_matches_cold_start_bitwise() {
    for policy in [PolicyKind::Zipcache, PolicyKind::H2o] {
        for chunk in [0usize, 3] {
            for kernel in [KernelChoice::Scalar, KernelChoice::Auto] {
                let ps = prompts();
                let cold: Vec<_> = ps
                    .iter()
                    .map(|p| cold_run(chunk, policy, kernel, p))
                    .collect();
                let mut cfg = cfg_with(chunk, true);
                cfg.policy = policy;
                cfg.quant.kernel = kernel;
                let mut engine = Engine::new(cfg).unwrap();
                // First prompt is the cold intern; the rest fork from it.
                for (i, p) in ps.iter().enumerate() {
                    let out = run_one(&mut engine, p);
                    assert_eq!(
                        out, cold[i],
                        "policy={policy:?} chunk={chunk} kernel={kernel} \
                         prompt {i}: warm output diverged from cold start"
                    );
                }
                // Re-running the interning prompt itself is also a hit
                // (covered stops at the last boundary <= n - 1).
                let again = run_one(&mut engine, &ps[0]);
                assert_eq!(again, cold[0]);
                assert_eq!(engine.metrics.prefix_misses, 1);
                assert_eq!(engine.metrics.prefix_hits, 3);
                // Boundary rule (DESIGN.md §16): each hit covers the
                // largest granule boundary inside the 57-token shared
                // span (the tails diverge there; the same boundary also
                // caps the full-prompt re-run at n - 1 = 59).
                let g = if chunk == 0 { DEFAULT_GRANULE } else { chunk };
                let shared = ps[0].len() - 3;
                assert_eq!(engine.metrics.prefill_tokens_skipped,
                           3 * (shared / g * g) as u64);
            }
        }
    }
}

#[test]
fn server_warm_matches_cold_across_shards_slots_chunks() {
    let ps = prompts();
    for chunk in [0usize, 3] {
        let cold: Vec<_> = ps
            .iter()
            .map(|p| cold_run(chunk, PolicyKind::Zipcache, KernelChoice::Auto, p))
            .collect();
        for shards in [1usize, 2] {
            for slots in [1usize, 2, 0] {
                let mut cfg = cfg_with(chunk, true);
                cfg.scheduler.shards = shards;
                cfg.memory.slots = slots;
                let server = Server::start(cfg).unwrap();
                // Two sequential rounds (each wait guarantees the intern
                // landed before the next lookup): round one interns on
                // the first request, round two is all warm — affinity
                // routing must send every later request to the shard
                // holding the segments even with shards = 2.
                for round in 0..2 {
                    for (i, p) in ps.iter().enumerate() {
                        let out = server
                            .handle
                            .submit(p.clone(), MAX_NEW)
                            .unwrap()
                            .wait()
                            .unwrap();
                        assert_eq!(
                            out.tokens, cold[i].0,
                            "chunk={chunk} shards={shards} slots={slots} \
                             round={round} request {i} diverged"
                        );
                    }
                }
                let snap = server.handle.metrics();
                assert_eq!(snap.total.prefix_misses, 1,
                           "chunk={chunk} shards={shards} slots={slots}");
                assert_eq!(snap.total.prefix_hits, 5,
                           "chunk={chunk} shards={shards} slots={slots}");
                assert!(snap.total.prefill_tokens_skipped > 0);
                assert!(snap.total.shared_segment_bytes > 0,
                        "store snapshot must surface through the server");
                server.shutdown().unwrap();
            }
        }
    }
}

#[test]
fn resident_bytes_never_count_shared_segments() {
    // Referenced by the `Session::resident_bytes` docs: a warm session's
    // byte accounting must equal the cold session's at every phase —
    // shared segment payload is charged once per shard (the store's
    // `shared_bytes` gauge), never per session.
    let p = prompts().remove(0);
    let mut warm_engine = Engine::new(cfg_with(3, true)).unwrap();
    let _ = run_one(&mut warm_engine, &p); // interns the prefix
    let mut cold_engine = Engine::new(cfg_with(3, false)).unwrap();
    let mut warm = warm_engine
        .begin_session(GenerationRequest::new(p.clone(), MAX_NEW))
        .unwrap();
    let mut cold = cold_engine
        .begin_session(GenerationRequest::new(p.clone(), MAX_NEW))
        .unwrap();
    assert!(warm.covered > 0 && !warm.shared.is_empty(), "must be a hit");
    assert_eq!(warm.resident_bytes(), cold.resident_bytes(),
               "mid-prefill accounting diverged");
    while warm.is_prefilling() {
        warm_engine.prefill_chunk(&mut warm).unwrap();
    }
    while cold.is_prefilling() {
        cold_engine.prefill_chunk(&mut cold).unwrap();
    }
    assert_eq!(warm.resident_bytes(), cold.resident_bytes(),
               "decode-ready accounting diverged");
    while !warm.is_done() {
        warm_engine.decode_step(&mut warm).unwrap();
        cold_engine.decode_step(&mut cold).unwrap();
        assert_eq!(warm.resident_bytes(), cold.resident_bytes());
    }
}

#[test]
fn mid_prefill_drop_on_hit_path_releases_pins() {
    // chunk = 2 leaves two suffix chunks after the hit (covered = 56 of
    // 60), so the drop lands genuinely mid-prefill.
    let ps = prompts();
    let cold = cold_run(2, PolicyKind::Zipcache, KernelChoice::Auto, &ps[1]);
    let mut engine = Engine::new(cfg_with(2, true)).unwrap();
    let _ = run_one(&mut engine, &ps[0]);
    let store = engine.prefix_store().unwrap().clone();
    assert_eq!(store.refs(), 0, "completed sessions hold no pins");
    let mut s = engine
        .begin_session(GenerationRequest::new(ps[1].clone(), MAX_NEW))
        .unwrap();
    assert!(s.covered > 0 && s.is_prefilling());
    assert!(store.refs() > 0, "the live warm session pins its segments");
    engine.prefill_chunk(&mut s).unwrap();
    assert!(s.is_prefilling(), "drop must land between chunks");
    drop(s); // cancel mid-prefill: slot and pins both release
    assert_eq!(store.refs(), 0, "drop must release every pin");
    // The engine is unharmed and the same prompt still forks bitwise.
    assert_eq!(run_one(&mut engine, &ps[1]), cold);
}

#[test]
fn eviction_under_churn_drains_all_gauges() {
    // Size the cap from one real prefix footprint so each rolled system
    // prompt evicts the previous one.
    let probe_trace = loadgen::shared_prefix_trace(64, 1, 0, 5);
    let mut probe_engine = Engine::new(cfg_with(3, true)).unwrap();
    let _ = run_one(&mut probe_engine, probe_trace.entries[0].sample.prompt());
    let one_prefix_bytes = probe_engine.prefix_store().unwrap().shared_bytes();
    assert!(one_prefix_bytes > 0);

    let mut cfg = cfg_with(3, true);
    cfg.prefix.max_bytes = one_prefix_bytes;
    let mut engine = Engine::new(cfg).unwrap();
    let store = engine.prefix_store().unwrap().clone();
    // 4 phases x 2 requests, the system prompt rolling every phase.
    let trace = loadgen::shared_prefix_trace(64, 2, 3, 9);
    for e in &trace.entries {
        let _ = run_one(&mut engine, e.sample.prompt());
    }
    assert!(store.evictions() > 0,
            "rolling prefixes under a tight cap must evict");
    assert!(engine.metrics.prefix_evictions > 0,
            "evictions must surface in the metrics snapshot");
    assert!(store.shared_bytes() <= one_prefix_bytes,
            "cap must hold with no live readers");
    assert_eq!(store.refs(), 0, "no sessions live: every pin released");
    store.evict_all();
    assert_eq!(store.entries(), 0);
    assert_eq!(store.shared_bytes(), 0,
               "gauges must drain to zero: churn leaks nothing");
}
