//! Chunked-prefill parity contracts (DESIGN.md §12), all runnable with
//! no artifacts on the sim backend:
//!
//! * **Chunk-size sweep** — for every `prefill_chunk` in
//!   {1, 3, 7, 16, prompt_len} and ragged prompt lengths (including a
//!   prompt shorter than one chunk), generated tokens and the retained
//!   snapshot's `content_digest` are bit-identical to the monolithic
//!   pass (`prefill_chunk = 0`), on both saliency paths (probe/flash
//!   and full-scores).
//! * **Slot-count sweep** — chunked prefill interleaved through the
//!   batcher under bounded residency (slots ∈ {1, 2, max_batch})
//!   changes no per-tag output.
//! * **Shard-count sweep** — the sharded server with chunking enabled
//!   matches the monolithic single-shard ground truth per tag.
//! * **Phase discipline** — a Prefilling session cannot decode, and
//!   `begin_session`/`prefill_chunk` advance exactly `ceil(n / chunk)`
//!   times.

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::server::Server;
use zipcache::workload::{Task, TaskGen};

const MAX_NEW: usize = 8;

fn sim_config(chunk: usize) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").unwrap();
    cfg.scheduler.prefill_chunk = chunk;
    cfg.quant.recompress_every = 4; // several streaming cycles per request
    cfg.parallelism = 1;
    cfg
}

/// Ragged prompt set: a 2-token prompt (shorter than every non-unit
/// chunk), a couple of mid-length code prompts, and a near-window
/// line-retrieval prompt (micro window = 64, decode headroom kept).
fn ragged_prompts() -> Vec<Vec<u16>> {
    let mut ps = vec![vec![7u16, 19]];
    let gen = TaskGen::new(Task::Code, 40);
    ps.push(gen.sample(1).prompt().to_vec());
    ps.push(gen.sample(2).prompt().to_vec());
    ps.push(TaskGen::new(Task::Lines(8), 56).sample(3).prompt().to_vec());
    ps
}

/// Run one prompt to completion at a given chunk size; returns the
/// generated tokens and the final retained snapshot's content digest.
fn run_one(cfg: &EngineConfig, p: &[u16]) -> (Vec<u16>, u64) {
    let mut engine = Engine::new(cfg.clone()).unwrap();
    let mut s = engine
        .start_session(GenerationRequest::new(p.to_vec(), MAX_NEW))
        .unwrap();
    while !s.is_done() {
        engine.decode_step(&mut s).unwrap();
    }
    let digest = s.compressed.as_ref().unwrap().content_digest();
    (s.generated.clone(), digest)
}

#[test]
fn chunk_size_sweep_matches_monolithic_bitwise() {
    for policy in [PolicyKind::Zipcache, PolicyKind::H2o] {
        for p in ragged_prompts() {
            let mut mono_cfg = sim_config(0);
            mono_cfg.policy = policy;
            let mono = run_one(&mono_cfg, &p);
            assert!(!mono.0.is_empty());
            for chunk in [1usize, 3, 7, 16, p.len()] {
                let mut cfg = sim_config(chunk);
                cfg.policy = policy;
                let out = run_one(&cfg, &p);
                assert_eq!(
                    out, mono,
                    "policy={policy:?} chunk={chunk} n={} diverged from \
                     monolithic (tokens or snapshot digest)",
                    p.len()
                );
            }
        }
    }
}

#[test]
fn chunk_zero_is_the_monolithic_path() {
    // `prefill_chunk = 0` must not even enter the Prefilling phase: the
    // session comes out of begin_session decode-ready, and no per-chunk
    // histogram samples are recorded.
    let mut engine = Engine::new(sim_config(0)).unwrap();
    let p = ragged_prompts().remove(1);
    let s = engine
        .begin_session(GenerationRequest::new(p, MAX_NEW))
        .unwrap();
    assert!(!s.is_prefilling());
    assert_eq!(engine.metrics.prefill_chunks, 0);
    assert_eq!(engine.metrics.prefill_chunk.count(), 0);
    assert_eq!(engine.metrics.prefill.count(), 1);
}

#[test]
fn prefill_phase_runs_ceil_n_over_chunk_times_and_blocks_decode() {
    let chunk = 5usize;
    let mut engine = Engine::new(sim_config(chunk)).unwrap();
    let p = ragged_prompts().remove(3); // the near-window prompt
    let n = p.len();
    assert!(n > chunk, "prompt must span several chunks");
    let mut s = engine
        .begin_session(GenerationRequest::new(p, MAX_NEW))
        .unwrap();
    assert!(s.is_prefilling());
    assert!(engine.decode_step(&mut s).is_err(),
            "decoding a Prefilling session must fail loudly");
    let mut steps = 0;
    while s.is_prefilling() {
        let finished = engine.prefill_chunk(&mut s).unwrap();
        steps += 1;
        assert_eq!(finished, !s.is_prefilling());
    }
    assert_eq!(steps, (n + chunk - 1) / chunk);
    assert_eq!(engine.metrics.prefill_chunks as usize, steps);
    assert_eq!(engine.metrics.prefill_chunk.count(), steps);
    assert_eq!(engine.metrics.prefill.count(), 1,
               "session-level total is one sample per session");
    // The now decode-ready session generates to completion normally.
    while !s.is_done() {
        engine.decode_step(&mut s).unwrap();
    }
    assert!(!s.generated.is_empty());
}

#[test]
fn batcher_slot_sweep_preserves_outputs_under_chunking() {
    // Chunked prefill interleaved through the batcher under bounded
    // residency: per-tag outputs must match the monolithic bare-engine
    // ground truth at every (chunk, slots) point — park/unpark pressure
    // and chunk interleaving are both invisible to generation.
    let ps = ragged_prompts();
    let mono: Vec<(Vec<u16>, u64)> =
        ps.iter().map(|p| run_one(&sim_config(0), p)).collect();
    for chunk in [1usize, 3, 16] {
        for slots in [1usize, 2, 0] {
            let mut cfg = sim_config(chunk);
            cfg.scheduler.max_batch = 4;
            cfg.memory.slots = slots;
            let mut engine = Engine::new(cfg).unwrap();
            let mut b = ContinuousBatcher::new(4, 16);
            for (tag, p) in ps.iter().enumerate() {
                b.submit(QueuedRequest {
                    request: GenerationRequest::new(p.clone(), MAX_NEW),
                    tag: tag as u64,
                })
                .unwrap();
            }
            let outs = b.run_to_completion(&mut engine).unwrap();
            assert_eq!(outs.len(), ps.len());
            for o in outs {
                assert_eq!(o.tokens, mono[o.tag as usize].0,
                           "chunk={chunk} slots={slots} tag={} diverged",
                           o.tag);
            }
        }
    }
}

#[test]
fn server_shard_sweep_preserves_outputs_under_chunking() {
    let ps = ragged_prompts();
    let mono: Vec<(Vec<u16>, u64)> =
        ps.iter().map(|p| run_one(&sim_config(0), p)).collect();
    for shards in [1usize, 2] {
        let mut cfg = sim_config(3);
        cfg.scheduler.shards = shards;
        let server = Server::start(cfg).unwrap();
        let handles: Vec<_> = ps
            .iter()
            .map(|p| server.handle.submit(p.clone(), MAX_NEW).unwrap())
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.wait().unwrap();
            assert_eq!(out.tokens, mono[i].0,
                       "shards={shards} request {i} diverged under chunking");
        }
        let snap = server.handle.metrics();
        assert!(snap.total.prefill_chunks > 0,
                "chunked entries never ran under the server");
        server.shutdown().unwrap();
    }
}
