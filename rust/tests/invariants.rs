//! Property-based invariants over the L3 substrates (via the in-tree
//! property harness `zipcache::util::prop` — the offline stand-in for
//! proptest).  These cover the coordinator-adjacent state machines: packing,
//! quantization planes, the compressed store, saliency selection, probe
//! strategies, and the batcher's routing/accounting.

use zipcache::kvcache::{CacheLayout, CompressedKV, PrecisionClass, QuantSpec};
use zipcache::quant::packing::PackedCodes;
use zipcache::quant::{Granularity, QuantizedPlane};
use zipcache::saliency::metric::{normalized_saliency, probe_normalized_saliency,
                                 select_salient};
use zipcache::saliency::{select_probes, ProbeStrategy};
use zipcache::util::prop::{check, Gen};

#[test]
fn prop_packing_roundtrip() {
    check("packing-roundtrip", 60, |g: &mut Gen| {
        let bits = *g.choice(&[1u8, 2, 4, 8]);
        let n = g.usize_in(0, 4096);
        let max = 1u16 << bits;
        let codes: Vec<u8> = (0..n)
            .map(|_| (g.rng.below(max as u64)) as u8)
            .collect();
        let packed = PackedCodes::pack(&codes, bits);
        if packed.unpack() != codes {
            return Err(format!("roundtrip failed bits={bits} n={n}"));
        }
        // random access agrees with bulk unpack
        for _ in 0..10.min(n) {
            let i = g.usize_in(0, n.saturating_sub(1));
            if n > 0 && packed.get(i) != codes[i] {
                return Err(format!("get({i}) mismatch"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_error_bounded_by_scale() {
    check("quant-error-bound", 40, |g: &mut Gen| {
        let rows = g.usize_in(1, 48);
        let cols = g.usize_in(1, 32);
        let bits = *g.choice(&[2u8, 4, 8]);
        let gran = *g.choice(&[Granularity::Token, Granularity::Channel,
                               Granularity::Group(8),
                               Granularity::ChannelSeparableToken]);
        let x = g.vec_f32(rows * cols, -8.0, 8.0);
        let q = QuantizedPlane::quantize(&x, rows, cols, bits, gran);
        let mut out = vec![0f32; x.len()];
        q.dequantize_into(&mut out);
        // error per element bounded by the worst-case step of its group;
        // bound loosely by global range / levels.
        let (mn, mx) = x.iter().fold((f32::MAX, f32::MIN),
                                     |(a, b), &v| (a.min(v), b.max(v)));
        let step = (mx - mn) / ((1u32 << bits) - 1) as f32;
        // CST rescaling can amplify by the channel scale (<= sqrt(8)).
        let bound = step * 3.0 + 1e-4;
        for (i, (&a, &b)) in x.iter().zip(&out).enumerate() {
            if (a - b).abs() > bound {
                return Err(format!(
                    "{gran:?} bits={bits} elem {i}: |{a} - {b}| > {bound}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_store_roundtrip_valid_mask() {
    check("store-valid-mask", 30, |g: &mut Gen| {
        let lay = CacheLayout {
            layers: g.usize_in(1, 3),
            heads: g.usize_in(1, 3),
            seq: g.usize_in(8, 24),
            d_head: g.usize_in(2, 16),
        };
        let n_tokens = g.usize_in(1, lay.seq);
        let k = g.vec_f32(lay.cache_len(), -4.0, 4.0);
        let v = g.vec_f32(lay.cache_len(), -4.0, 4.0);
        let classes: Vec<PrecisionClass> = (0..n_tokens)
            .map(|_| *g.choice(&[PrecisionClass::Fp16, PrecisionClass::Bits(4),
                                 PrecisionClass::Bits(2), PrecisionClass::Evicted]))
            .collect();
        let store = CompressedKV::compress(&k, &v, lay, &classes,
                                           QuantSpec::default());
        let mut ko = vec![0f32; lay.cache_len()];
        let mut vo = vec![0f32; lay.cache_len()];
        let mut va = vec![0f32; lay.seq];
        store.materialize_into(&mut ko, &mut vo, &mut va);
        for (t, c) in classes.iter().enumerate() {
            let want = if c.is_evicted() { 0.0 } else { 1.0 };
            if va[t] != want {
                return Err(format!("valid[{t}] = {} want {want}", va[t]));
            }
        }
        for t in n_tokens..lay.seq {
            if va[t] != 0.0 {
                return Err(format!("valid[{t}] beyond n_tokens"));
            }
        }
        // Ratio must exceed 1x whenever anything was quantized/evicted AND
        // the plane is big enough that per-subset parameter overhead cannot
        // dominate (at d_head=2 the two f16 (s,z) pairs outweigh the codes —
        // the same effect the paper's Appendix A quantifies for groupwise).
        if lay.d_head >= 8
            && n_tokens >= 8
            && classes.iter().any(|c| *c != PrecisionClass::Fp16)
            && store.compression_ratio() <= 1.0
        {
            return Err(format!("ratio {} <= 1", store.compression_ratio()));
        }
        Ok(())
    });
}

#[test]
fn prop_select_salient_count_and_monotone() {
    check("select-salient", 50, |g: &mut Gen| {
        let n = g.usize_in(1, 200);
        let sal = g.vec_f32(n, 0.0, 1.0);
        let ratio = g.f32_in(0.0, 1.0) as f64;
        let mask = select_salient(&sal, n, ratio);
        let k = mask.iter().filter(|&&m| m).count();
        let want = ((n as f64) * ratio).round() as usize;
        if k != want.min(n) {
            return Err(format!("selected {k} want {want}"));
        }
        // every selected token's saliency >= every unselected token's
        let min_sel = mask.iter().zip(&sal).filter(|(m, _)| **m)
            .map(|(_, &s)| s).fold(f32::MAX, f32::min);
        let max_unsel = mask.iter().zip(&sal).filter(|(m, _)| !**m)
            .map(|(_, &s)| s).fold(f32::MIN, f32::max);
        if k > 0 && k < n && min_sel < max_unsel - 1e-6 {
            return Err(format!("not top-k: {min_sel} < {max_unsel}"));
        }
        Ok(())
    });
}

#[test]
fn prop_probe_selection_well_formed() {
    check("probe-selection", 50, |g: &mut Gen| {
        let n = g.usize_in(1, 300);
        let strat = *g.choice(&[ProbeStrategy::Random, ProbeStrategy::Recent,
                                ProbeStrategy::RandomRecent]);
        let seed = g.rng.next_u64();
        let p = select_probes(strat, n, 0.1, None, seed);
        if p.is_empty() {
            return Err("empty probes".into());
        }
        if !p.windows(2).all(|w| w[0] < w[1]) {
            return Err("not sorted/unique".into());
        }
        if p.iter().any(|&i| i >= n) {
            return Err("out of range".into());
        }
        // determinism
        if p != select_probes(strat, n, 0.1, None, seed) {
            return Err("nondeterministic".into());
        }
        Ok(())
    });
}

#[test]
fn prop_probe_saliency_exact_when_full() {
    check("probe-saliency-exact", 30, |g: &mut Gen| {
        let l = g.usize_in(2, 40);
        // random causal attention matrix with normalized rows
        let mut a = vec![0f32; l * l];
        for r in 0..l {
            let mut sum = 0f32;
            for c in 0..=r {
                let v = g.f32_in(0.01, 1.0);
                a[r * l + c] = v;
                sum += v;
            }
            for c in 0..=r {
                a[r * l + c] /= sum;
            }
        }
        let idx: Vec<usize> = (0..l).collect();
        let exact = normalized_saliency(&a, l, l);
        let approx = probe_normalized_saliency(&a, &idx, l);
        for (i, (x, y)) in exact.iter().zip(&approx).enumerate() {
            if (x - y).abs() > 1e-5 {
                return Err(format!("col {i}: {x} vs {y}"));
            }
        }
        Ok(())
    });
}
