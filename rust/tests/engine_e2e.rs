//! Engine/coordinator/server integration tests over the micro artifacts:
//! every policy generates end-to-end; the batcher interleaves correctly;
//! the server round-trips requests; streaming recompression triggers.

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::server::Server;
use zipcache::workload::{Task, TaskGen};

fn config(policy: PolicyKind) -> Option<EngineConfig> {
    let dir = std::env::var("ZIPCACHE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    let mut cfg = EngineConfig::load_default(dir, "micro").ok()?;
    cfg.policy = policy;
    Some(cfg)
}

#[test]
fn every_policy_generates() {
    let Some(cfg) = config(PolicyKind::Zipcache) else { return };
    let mut engine = Engine::new(cfg).unwrap();
    let info = engine.runtime().model_info().clone();
    let gen = TaskGen::new(Task::Code, info.max_seq - 4);
    let sample = gen.sample(9);
    for policy in PolicyKind::ALL {
        engine.set_policy(policy);
        let out = engine.generate(sample.prompt(), 4).unwrap();
        assert!(!out.tokens.is_empty(), "{policy}");
        assert!(out.tokens.len() <= 4);
        assert!(out.prefill_ms > 0.0);
        match policy {
            PolicyKind::Fp16 => {
                // fp16 rounding only: ratio ~2x vs fp16 baseline? No: the
                // store keeps f32->f16 rows accounted at 2B = exactly 1x.
                assert!((out.compression_ratio - 1.0).abs() < 0.05, "{policy}");
            }
            PolicyKind::H2o => {
                assert!(out.compression_ratio > 2.0, "{policy}: {}",
                        out.compression_ratio);
            }
            PolicyKind::Kivi => {
                // short prompt: the fp16 recent window covers most of the
                // cache, collapsing KIVI's ratio — exactly the paper's
                // Table B observation.
                assert!(out.compression_ratio >= 1.0, "{policy}: {}",
                        out.compression_ratio);
            }
            _ => {
                assert!(out.compression_ratio > 1.5,
                        "{policy}: {}", out.compression_ratio);
            }
        }
    }
}

#[test]
fn deterministic_generation() {
    let Some(cfg) = config(PolicyKind::Zipcache) else { return };
    let mut e1 = Engine::new(cfg.clone()).unwrap();
    let mut e2 = Engine::new(cfg).unwrap();
    let info = e1.runtime().model_info().clone();
    let s = TaskGen::new(Task::Gsm, info.max_seq - 4).sample(21);
    let o1 = e1.generate(s.prompt(), 4).unwrap();
    let o2 = e2.generate(s.prompt(), 4).unwrap();
    assert_eq!(o1.tokens, o2.tokens);
    assert_eq!(o1.cache_bytes, o2.cache_bytes);
}

#[test]
fn zipcache_beats_mikv_on_planted_saliency() {
    // The engine-level version of the paper's core claim is statistical;
    // here we only require both to run and produce sane mixed-precision
    // stats on the same prompt (accuracy comparisons live in the benches).
    let Some(cfg) = config(PolicyKind::Zipcache) else { return };
    let mut engine = Engine::new(cfg).unwrap();
    let info = engine.runtime().model_info().clone();
    let s = TaskGen::new(Task::Lines(6), info.max_seq - 4).sample(33);
    let zip = engine.generate(s.prompt(), 2).unwrap();
    engine.set_policy(PolicyKind::Mikv);
    let mikv = engine.generate(s.prompt(), 2).unwrap();
    // same bit budget -> comparable measured ratios (within 20%)
    assert!((zip.compression_ratio / mikv.compression_ratio - 1.0).abs() < 0.2);
}

#[test]
fn batcher_interleaves_and_completes() {
    let Some(mut cfg) = config(PolicyKind::Zipcache) else { return };
    cfg.scheduler.max_batch = 2;
    let mut engine = Engine::new(cfg).unwrap();
    let info = engine.runtime().model_info().clone();
    let gen = TaskGen::new(Task::Code, info.max_seq - 4);
    let mut b = ContinuousBatcher::new(2, 8);
    for tag in 0..5u64 {
        b.submit(QueuedRequest {
            request: GenerationRequest::new(gen.sample(tag).prompt().to_vec(), 3),
            tag,
        }).unwrap();
    }
    let outcomes = b.run_to_completion(&mut engine).unwrap();
    assert_eq!(outcomes.len(), 5);
    let tags: Vec<u64> = outcomes.iter().map(|o| o.tag).collect();
    assert_eq!(tags, vec![0, 1, 2, 3, 4]);
    assert!(outcomes.iter().all(|o| !o.tokens.is_empty()));
    assert_eq!(engine.metrics.requests_completed, 5);
}

#[test]
fn server_round_trips_concurrent_requests() {
    let Some(mut cfg) = config(PolicyKind::Zipcache) else { return };
    cfg.scheduler.max_batch = 2;
    let server = Server::start(cfg).unwrap();
    let gen = TaskGen::new(Task::Code, 60);
    let mut handles = Vec::new();
    for i in 0..4u64 {
        let h = server.handle.clone();
        let prompt = gen.sample(i).prompt().to_vec();
        handles.push(std::thread::spawn(move || h.generate(prompt, 2)));
    }
    for h in handles {
        let out = h.join().unwrap().unwrap();
        assert!(!out.tokens.is_empty());
    }
    server.shutdown().unwrap();
}

#[test]
fn streaming_recompression_triggers() {
    let Some(mut cfg) = config(PolicyKind::Zipcache) else { return };
    cfg.quant.recompress_every = 4; // force several cycles in a short decode
    let mut engine = Engine::new(cfg).unwrap();
    let info = engine.runtime().model_info().clone();
    let s = TaskGen::new(Task::Code, info.max_seq / 2).sample(3);
    let mut sess = engine
        .start_session(GenerationRequest::new(s.prompt().to_vec(), 16))
        .unwrap();
    while !sess.is_done() {
        engine.decode_step(&mut sess).unwrap();
    }
    assert!(engine.metrics.compress.count() >= 1,
            "recompression never triggered");
}

#[test]
fn window_overflow_rejected() {
    let Some(cfg) = config(PolicyKind::Zipcache) else { return };
    let mut engine = Engine::new(cfg).unwrap();
    let info = engine.runtime().model_info().clone();
    let prompt = vec![1u16; info.max_seq];
    assert!(engine
        .start_session(GenerationRequest::new(prompt, 4))
        .is_err());
    assert!(engine
        .start_session(GenerationRequest::new(vec![], 4))
        .is_err());
}
