"""L2 model invariants: shapes, path equivalence, decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile.aot import probe_count
from compile.model import (CONFIGS, decode_step, init_params, loss_fn,
                           prefill_flash, prefill_full, rmsnorm)

CFG = CONFIGS["micro"]


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def _sample_inputs(seed=5):
    s = D.train_sample(D.SplitMix64(seed), CFG.max_seq)
    n = len(s.tokens)
    toks = np.zeros(CFG.max_seq, np.int32)
    toks[:n] = s.tokens
    valid = np.zeros(CFG.max_seq, np.float32)
    valid[:n] = 1.0
    P = probe_count(CFG)
    pr = np.sort(np.r_[np.arange(n - P // 2, n),
                       np.arange(0, P - P // 2)]).astype(np.int32)
    return s, jnp.asarray(toks), jnp.asarray(valid), jnp.asarray(pr), n


def test_prefill_full_shapes(params):
    _, toks, valid, _, _ = _sample_inputs()
    r = prefill_full(params, CFG, toks, valid)
    S, L, H, dh, V = (CFG.max_seq, CFG.n_layers, CFG.n_heads, CFG.d_head,
                      CFG.vocab)
    assert r["logits"].shape == (S, V)
    assert r["kcache"].shape == (L, H, S, dh)
    assert r["vcache"].shape == (L, H, S, dh)
    assert r["acc_saliency"].shape == (L, S)
    assert r["norm_saliency"].shape == (L, S)


def test_prefill_paths_agree_on_valid_region(params):
    _, toks, valid, pr, n = _sample_inputs()
    rf = prefill_full(params, CFG, toks, valid)
    rl = prefill_flash(params, CFG, toks, valid, pr)
    np.testing.assert_allclose(rf["logits"][:n], rl["logits"][:n],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(rf["kcache"][:, :, :n], rl["kcache"][:, :, :n],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(rf["vcache"][:, :, :n], rl["vcache"][:, :, :n],
                               rtol=1e-4, atol=1e-4)


def test_decode_matches_extended_prefill(params):
    """decode_step at pos n == prefill over n+1 tokens, row n."""
    s, toks, valid, _, n = _sample_inputs()
    rf = prefill_full(params, CFG, toks, valid)
    nxt = jnp.asarray(s.tokens[3], jnp.int32)
    r = decode_step(params, CFG, nxt, jnp.asarray(n, jnp.int32),
                    rf["kcache"], rf["vcache"], valid)
    toks2 = np.asarray(toks).copy()
    toks2[n] = int(nxt)
    valid2 = np.asarray(valid).copy()
    valid2[n] = 1.0
    rf2 = prefill_full(params, CFG, jnp.asarray(toks2), jnp.asarray(valid2))
    np.testing.assert_allclose(r["logits"], rf2["logits"][n],
                               rtol=3e-3, atol=3e-3)
    # new KV rows must equal the extended prefill's row n
    np.testing.assert_allclose(r["k_new"], rf2["kcache"][:, :, n],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(r["v_new"], rf2["vcache"][:, :, n],
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_row_normalized(params):
    """a_row over cached tokens + the (unreported) self weight == 1; so the
    reported row must sum to < 1 and >= 0 elementwise."""
    s, toks, valid, _, n = _sample_inputs()
    rf = prefill_full(params, CFG, toks, valid)
    r = decode_step(params, CFG, jnp.asarray(7, jnp.int32),
                    jnp.asarray(n, jnp.int32), rf["kcache"], rf["vcache"],
                    valid)
    a = r["a_row"]
    assert float(a.min()) >= 0.0
    sums = jnp.sum(a, axis=-1)
    assert float(sums.max()) < 1.0 + 1e-5
    assert float(sums.min()) > 0.0


def test_decode_respects_validity_mask(params):
    """Evicted (valid=0) positions must receive zero attention."""
    s, toks, valid, _, n = _sample_inputs()
    rf = prefill_full(params, CFG, toks, valid)
    ev = np.asarray(valid).copy()
    ev[2:6] = 0.0  # evict a block
    r = decode_step(params, CFG, jnp.asarray(7, jnp.int32),
                    jnp.asarray(n, jnp.int32), rf["kcache"], rf["vcache"],
                    jnp.asarray(ev))
    assert float(jnp.abs(r["a_row"][:, 2:6]).max()) == 0.0


def test_saliency_nonnegative_and_masked(params):
    _, toks, valid, pr, n = _sample_inputs()
    rl = prefill_flash(params, CFG, toks, valid, pr)
    sal = rl["norm_saliency"]
    assert float(sal.min()) >= 0.0
    assert float(jnp.abs(sal[:, n:]).max()) == 0.0  # padded region zeroed


def test_loss_decreases_over_few_steps(params):
    """Sanity: two gradient steps reduce the training loss on a fixed batch."""
    import compile.train as T
    rng = D.SplitMix64(77)
    toks, tgts, mask = T.make_batch(rng, 8, CFG.max_seq)
    p = params
    opt = T.adam_init(p)
    l0 = float(loss_fn(p, CFG, toks, tgts, mask))
    for _ in range(5):
        loss, grads = jax.value_and_grad(loss_fn)(p, CFG, toks, tgts, mask)
        p, opt = T.adam_update(p, grads, opt, 1e-3)
    l1 = float(loss_fn(p, CFG, toks, tgts, mask))
    assert l1 < l0


def test_rmsnorm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16)) * 100.0
    y = rmsnorm(x, jnp.ones((16,)))
    ms = jnp.mean(jnp.square(y), axis=-1)
    np.testing.assert_allclose(ms, jnp.ones(8), rtol=1e-3)


def test_param_count_matches_formula():
    for cfg in CONFIGS.values():
        p = init_params(cfg, seed=0)
        total = sum(int(np.prod(x.shape))
                    for x in jax.tree_util.tree_leaves(p))
        assert total == cfg.n_params, (cfg.name, total, cfg.n_params)
