"""Generate ``rust/tests/fixtures/cross_layer.json``.

The fixture pins the cross-layer determinism contract (DESIGN.md §2):
the Rust workload generators in ``rust/src/workload`` must reproduce the
Python corpus generators in ``python/compile/data.py`` bit-for-bit.  Run
from the repo root whenever the generators change — and remember that a
generator change also invalidates trained artifacts:

    python3 python/tests/make_cross_layer_fixture.py
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.data import SplitMix64, gen_line_retrieval, gen_task  # noqa: E402

SEEDS = [0, 1, 2, 3, 4, 7, 11, 42, 123, 10_000]


def case(seed: int, sample) -> dict:
    return {
        "seed": seed,
        "tokens": sample.tokens,
        "prompt_len": sample.prompt_len,
        "answer": sample.answer,
        "span": list(sample.salient_span),
    }


def main() -> None:
    rng = SplitMix64(0)
    fixture = {
        # u64 draws exceed JSON's exact-integer range -> stored as strings.
        "splitmix": [str(rng.next_u64()) for _ in range(16)],
        "gsm": [case(s, gen_task("gsm", s, 256)) for s in SEEDS],
        "lines": [case(s, gen_line_retrieval(s, 20)) for s in SEEDS],
        "code": [case(s, gen_task("code", s, 256)) for s in SEEDS],
    }
    out = os.path.join(os.path.dirname(__file__), "..", "..",
                       "rust", "tests", "fixtures", "cross_layer.json")
    out = os.path.normpath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(fixture, f, indent=1)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
