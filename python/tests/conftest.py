"""Shared fixtures for the kernel/model test suite."""

import os
import sys

# Allow running pytest from either repo root or python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
