"""FlashAttention + probe-saliency Pallas kernels vs ref oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (flash_attention, flash_attention_mha,
                             probe_attention_saliency, select_probe_indices)
from compile.kernels import ref

ATOL = 3e-5
RTOL = 3e-5


def _qkv(lq, lk, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (lq, d), jnp.float32)
    k = jax.random.normal(ks[1], (lk, d), jnp.float32)
    v = jax.random.normal(ks[2], (lk, d), jnp.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# FlashAttention == standard attention (paper Fig. 4 equivalence)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("l,d", [(16, 8), (64, 16), (128, 32), (96, 24)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_standard(l, d, causal):
    q, k, v = _qkv(l, l, d, seed=l + d)
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("lq,lk", [(8, 64), (16, 128), (1, 32), (32, 32)])
def test_flash_decode_alignment(lq, lk):
    """lq < lk (decode-style): rows align to the end of the key sequence."""
    q, k, v = _qkv(lq, lk, 16, seed=lq * 7 + lk)
    got = flash_attention(q, k, v, block_q=8, block_k=16)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    l=st.sampled_from([16, 32, 48, 96]),
    d=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_flash_hypothesis_blocks(l, d, bq, bk, seed):
    """Output must be block-shape invariant (pure schedule change)."""
    q, k, v = _qkv(l, l, d, seed=seed)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_flash_mha():
    h, l, d = 4, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (h, l, d))
    k = jax.random.normal(ks[1], (h, l, d))
    v = jax.random.normal(ks[2], (h, l, d))
    got = flash_attention_mha(q, k, v, block_q=16, block_k=16)
    for hh in range(h):
        np.testing.assert_allclose(got[hh], ref.flash_attention(q[hh], k[hh], v[hh]),
                                   rtol=1e-4, atol=1e-4)


def test_flash_extreme_scores_no_overflow():
    """Online softmax must survive large score magnitudes."""
    q, k, v = _qkv(32, 32, 8, seed=5)
    got = flash_attention(q * 30.0, k * 30.0, v, block_q=8, block_k=8)
    want = ref.flash_attention(q * 30.0, k * 30.0, v)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Probe attention + normalized saliency (Eqs. 8/9)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("l,d,p", [(32, 8, 4), (64, 16, 8), (128, 32, 12)])
def test_probe_attention_matches_ref(l, d, p):
    q, k, _ = _qkv(l, l, d, seed=l * 3)
    idx = jnp.sort(jax.random.choice(jax.random.PRNGKey(p), l, (p,),
                                     replace=False)).astype(jnp.int32)
    a_got, sal_got = probe_attention_saliency(q, k, idx, block_k=16)
    a_want = ref.probe_attention(q, k, idx)
    sal_want = ref.probe_saliency(q, k, idx)
    np.testing.assert_allclose(a_got, a_want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sal_got, sal_want, rtol=1e-4, atol=1e-4)


def test_probe_rows_sum_to_one():
    q, k, _ = _qkv(64, 64, 16, seed=11)
    idx = jnp.asarray([3, 17, 40, 63], jnp.int32)
    a, _ = probe_attention_saliency(q, k, idx, block_k=16)
    np.testing.assert_allclose(jnp.sum(a, axis=-1), jnp.ones(4), rtol=1e-5,
                               atol=1e-5)


def test_probe_causality():
    """Probe row i must place zero mass on keys beyond position i."""
    q, k, _ = _qkv(64, 64, 16, seed=12)
    idx = jnp.asarray([5, 30], jnp.int32)
    a, _ = probe_attention_saliency(q, k, idx, block_k=16)
    assert float(jnp.abs(a[0, 6:]).max()) == 0.0
    assert float(jnp.abs(a[1, 31:]).max()) == 0.0


def test_probe_saliency_approximates_full_metric():
    """§4.3: saliency from all-rows probe == exact Eq. 8."""
    l, d = 64, 16
    q, k, _ = _qkv(l, l, d, seed=13)
    idx = jnp.arange(l, dtype=jnp.int32)
    _, sal = probe_attention_saliency(q, k, idx, block_k=16)
    _, a_full = ref.standard_attention(q, k, k)  # v unused for scores
    want = ref.normalized_saliency(a_full)
    np.testing.assert_allclose(sal, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    l=st.sampled_from([32, 64, 96]),
    p=st.integers(2, 12),
    seed=st.integers(0, 2**16),
)
def test_probe_hypothesis(l, p, seed):
    q, k, _ = _qkv(l, l, 16, seed=seed)
    idx = jnp.sort(jax.random.choice(jax.random.PRNGKey(seed ^ 1), l, (p,),
                                     replace=False)).astype(jnp.int32)
    a_got, sal_got = probe_attention_saliency(q, k, idx, block_k=16)
    np.testing.assert_allclose(a_got, ref.probe_attention(q, k, idx),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(sal_got, ref.probe_saliency(q, k, idx),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Saliency metric semantics (paper §4.2, Fig. 3)
# ---------------------------------------------------------------------------


def test_accumulated_scores_biased_to_early_tokens():
    """Fig. 3(a): under uniform attention the accumulated saliency of token
    0 is the harmonic series (~ln l) while the last token gets 1/l — a huge
    spread.  Normalization shrinks that spread by an order of magnitude."""
    l = 32
    a = jnp.tril(jnp.ones((l, l))) / jnp.arange(1, l + 1)[:, None]
    acc = ref.accumulated_saliency(a)
    nrm = ref.normalized_saliency(a)
    assert float(acc[0]) > 3.0 * float(acc[-1])
    spread = lambda v: float(jnp.max(v) / jnp.min(v))
    assert spread(nrm) < spread(acc) / 10.0, (spread(nrm), spread(acc))


def test_normalized_saliency_finds_planted_hot_token():
    """Plant a column that every later row attends to strongly: normalized
    saliency must rank it (and not token 0) on top among non-self columns."""
    l, d = 64, 16
    key = jax.random.PRNGKey(7)
    k = jax.random.normal(key, (l, d))
    hot = 37
    q = 0.05 * jax.random.normal(jax.random.PRNGKey(8), (l, d))
    q = q.at[hot + 1:].add(3.0 * k[hot])  # later queries point at `hot`
    _, a = ref.standard_attention(q, k, k)
    nrm = ref.normalized_saliency(a)
    assert int(jnp.argmax(nrm[: l - 1])) == hot


def test_select_probe_indices_hybrid():
    idx = np.asarray(select_probe_indices(100, 0.05, 0.05, seed=1))
    assert len(set(idx.tolist())) == len(idx)
    assert (idx[-5:] == np.arange(95, 100)).all()  # recent block present
    assert (idx[:-5] < 95).all()
    assert (np.diff(idx) > 0).all()
