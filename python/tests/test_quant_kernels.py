"""Pallas quantization kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/bit-widths per the repro contract: the
kernels must agree with the oracle for every granularity the paper's
Table 1 compares.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (channel_quant, cst_quant, group_quant,
                             token_quant, zipcache_quant_kv)
from compile.kernels import ref

ATOL = 1e-5
RTOL = 1e-5


def _data(l, hd, seed=0, outliers=True):
    """KV-like data: gaussian tokens with per-channel outlier magnitudes,
    matching the paper's Figure 2 observation (channel outliers in K/V)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (l, hd), jnp.float32)
    if outliers:
        scale = jnp.exp(1.5 * jax.random.normal(k2, (1, hd)))
        x = x * scale
    return x


KERNELS = [
    ("token", token_quant, ref.token_quant),
    ("channel", channel_quant, ref.channel_quant),
    ("cst", cst_quant, ref.cst_quant),
]


@pytest.mark.parametrize("name,kern,oracle", KERNELS)
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("l,hd", [(16, 8), (64, 32), (128, 64)])
def test_quant_matches_oracle(name, kern, oracle, bits, l, hd):
    x = _data(l, hd, seed=l + bits)
    got = kern(x, bits)
    want = oracle(x, bits)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("group", [8, 16, 32])
def test_group_quant_matches_oracle(bits, group):
    x = _data(64, 64, seed=bits * group)
    np.testing.assert_allclose(
        group_quant(x, bits, group), ref.group_quant(x, bits, group),
        rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    l=st.sampled_from([8, 24, 48, 96]),
    hd=st.sampled_from([8, 16, 48]),
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**16),
    outliers=st.booleans(),
)
def test_cst_quant_hypothesis(l, hd, bits, seed, outliers):
    """Property sweep: CST kernel == oracle over random shapes/dists,
    including non-power-of-two block splits."""
    x = _data(l, hd, seed=seed, outliers=outliers)
    np.testing.assert_allclose(
        cst_quant(x, bits, block_l=32), ref.cst_quant(x, bits),
        rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    l=st.sampled_from([8, 32, 64]),
    hd=st.sampled_from([8, 32]),
    bits=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_token_channel_hypothesis(l, hd, bits, seed):
    x = _data(l, hd, seed=seed)
    np.testing.assert_allclose(token_quant(x, bits), ref.token_quant(x, bits),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(channel_quant(x, bits),
                               ref.channel_quant(x, bits),
                               rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Quantization *quality* invariants — the paper's §4.1 claims
# ---------------------------------------------------------------------------


def _mse(a, b):
    return float(jnp.mean(jnp.square(a - b)))


def test_cst_beats_plain_tokenwise_under_channel_outliers():
    """Paper Table 1 ordering: with channel outliers, CST quantization has
    lower error than plain tokenwise quantization at the same bit-width."""
    x = _data(128, 64, seed=7, outliers=True)
    err_cst = _mse(ref.cst_quant(x, 4), x)
    err_tok = _mse(ref.token_quant(x, 4), x)
    assert err_cst < err_tok, (err_cst, err_tok)


def test_groupwise_close_to_cst_but_more_params():
    """Groupwise is the quality ceiling; CST should be in its ballpark
    (within 4x MSE) while using ~hd instead of l*hd/n parameters."""
    x = _data(128, 64, seed=9, outliers=True)
    err_grp = _mse(ref.group_quant(x, 4, 32), x)
    err_cst = _mse(ref.cst_quant(x, 4), x)
    assert err_cst < 4.0 * err_grp, (err_cst, err_grp)


def test_higher_bits_lower_error():
    x = _data(64, 32, seed=11)
    errs = [_mse(ref.cst_quant(x, b), x) for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_quant_idempotent():
    """Fake-quantizing an already fake-quantized tensor drifts far less than
    the first quantization hurt (channel scales shift slightly between
    passes, so exact idempotence does not hold for CST)."""
    x = _data(64, 32, seed=13)
    q1 = ref.cst_quant(x, 4)
    q2 = ref.cst_quant(q1, 4)
    assert _mse(q1, q2) < 0.3 * _mse(q1, x), (_mse(q1, q2), _mse(q1, x))


def test_quant_preserves_constant_rows():
    """Degenerate input (all-equal token) must survive without NaN."""
    x = jnp.ones((16, 8), jnp.float32) * 3.5
    for fn in (ref.token_quant, ref.channel_quant, ref.cst_quant):
        out = fn(x, 4)
        assert bool(jnp.isfinite(out).all())
        np.testing.assert_allclose(out, x, atol=1e-5)


def test_zero_input():
    x = jnp.zeros((16, 8), jnp.float32)
    out = ref.cst_quant(x, 2)
    np.testing.assert_allclose(out, x, atol=1e-6)


# ---------------------------------------------------------------------------
# Mixed-precision KV quantization (ZipCache config)
# ---------------------------------------------------------------------------


def test_zipcache_quant_kv_mixed_precision():
    k = _data(64, 32, seed=21)
    v = _data(64, 32, seed=22)
    mask = jnp.zeros((64,), bool).at[:16].set(True)
    kq, vq = zipcache_quant_kv(k, v, mask, bits_high=4, bits_low=2)
    # Salient rows must match the hi-bit reference, regular rows the lo-bit.
    k_hi = ref.channel_quant(k, 4)
    k_lo = ref.channel_quant(k, 2)
    v_hi = ref.cst_quant(v, 4)
    v_lo = ref.cst_quant(v, 2)
    np.testing.assert_allclose(kq[:16], k_hi[:16], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(kq[16:], k_lo[16:], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(vq[:16], v_hi[:16], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(vq[16:], v_lo[16:], rtol=1e-5, atol=1e-5)


def test_zipcache_salient_tokens_have_lower_error():
    k = _data(64, 32, seed=31)
    v = _data(64, 32, seed=32)
    mask = jnp.zeros((64,), bool).at[::4].set(True)
    kq, vq = zipcache_quant_kv(k, v, mask)
    err_sal = _mse(vq[mask], v[mask])
    err_reg = _mse(vq[~mask], v[~mask])
    assert err_sal < err_reg
