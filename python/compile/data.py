"""Synthetic task corpus shared between the Python training path and the
Rust workload generators (``rust/src/workload``).

The three paper workloads are reproduced as members of one associative-
recall family (DESIGN.md §2):

  * ``gsm``            — long "chain-of-thought" body of distractor facts
                         with the *question* at the end (Fig. 3(b) layout):
                         the queried pair sits mid-sequence, the query tokens
                         sit at the very end.
  * ``line_retrieval`` — N lines ``LINE <d1 d2> : <val>``; the query names a
                         line index and the model must return that line's
                         value (LongEval LRT structure, Fig. 5 / Table A).
  * ``code``           — short prompts (l≈120, Table B's regime) of the same
                         structure.

DETERMINISM CONTRACT: every sequence is a pure function of ``(task, seed)``
via SplitMix64.  The Rust side re-implements ``SplitMix64`` bit-for-bit
(``rust/src/workload/rng.rs``) and the token layouts below; cross-layer
tests compare generated streams exactly.

Token map (vocab = 256):
  0 PAD | 1 BOS | 2 SEP | 3 QUERY | 4 EOS | 5 NL | 6 LINE
  16..79    KEY tokens   (64)
  80..143   VAL tokens   (64)
  144..207  FILLER tokens(64)
  208..217  DIGIT tokens (10)
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

PAD, BOS, SEP, QUERY, EOS, NL, LINE = 0, 1, 2, 3, 4, 5, 6
KEY0, NKEY = 16, 64
VAL0, NVAL = 80, 64
FIL0, NFIL = 144, 64
DIG0 = 208


class SplitMix64:
    """SplitMix64 PRNG — tiny, seedable, trivially portable to Rust."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        """Uniform in [0, n) via modulo (bias negligible for n << 2^64)."""
        return self.next_u64() % n

    def shuffle(self, xs: list) -> None:
        """Fisher-Yates, in place."""
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


@dataclasses.dataclass
class Sample:
    tokens: List[int]      # full sequence incl. answer (for training)
    prompt_len: int        # tokens[:prompt_len] is the serving-time prompt
    answer: List[int]      # [val_token, EOS]
    salient_span: Tuple[int, int]  # [start, end) of the queried pair


def _pair_tokens(key_tok: int, val_tok: int) -> List[int]:
    return [key_tok, SEP, val_tok, NL]


def gen_recall(seed: int, n_pairs: int, n_filler: int) -> Sample:
    """Core associative recall: pairs + filler, query at the end."""
    rng = SplitMix64(seed)
    keys = list(range(NKEY))
    rng.shuffle(keys)
    keys = keys[:n_pairs]
    vals = [rng.below(NVAL) for _ in range(n_pairs)]
    qi = rng.below(n_pairs)

    body: List[List[int]] = [
        _pair_tokens(KEY0 + k, VAL0 + v) for k, v in zip(keys, vals)
    ]
    for _ in range(n_filler):
        body.append([FIL0 + rng.below(NFIL), NL])
    rng.shuffle(body)

    toks: List[int] = [BOS]
    sal = (0, 0)
    for chunk in body:
        if chunk[0] == KEY0 + keys[qi]:
            sal = (len(toks), len(toks) + len(chunk))
        toks.extend(chunk)
    toks.extend([QUERY, KEY0 + keys[qi], SEP])
    prompt_len = len(toks)
    answer = [VAL0 + vals[qi], EOS]
    toks.extend(answer)
    return Sample(toks, prompt_len, answer, sal)


def fits(sample: Sample, max_seq: int) -> bool:
    return len(sample.tokens) <= max_seq


def gen_line_retrieval(seed: int, n_lines: int) -> Sample:
    """LongEval-style line retrieval with 2-digit line indices (<=100 lines
    per hundred-block; indices are sampled unique in [0, 100))."""
    assert n_lines <= 100
    rng = SplitMix64(seed)
    idxs = list(range(100))
    rng.shuffle(idxs)
    idxs = idxs[:n_lines]
    vals = [rng.below(NVAL) for _ in range(n_lines)]
    qi = rng.below(n_lines)

    toks: List[int] = [BOS]
    sal = (0, 0)
    for i, (ix, v) in enumerate(zip(idxs, vals)):
        start = len(toks)
        toks.extend([LINE, DIG0 + ix // 10, DIG0 + ix % 10, SEP, VAL0 + v, NL])
        if i == qi:
            sal = (start, len(toks))
    toks.extend([QUERY, DIG0 + idxs[qi] // 10, DIG0 + idxs[qi] % 10, SEP])
    prompt_len = len(toks)
    answer = [VAL0 + vals[qi], EOS]
    toks.extend(answer)
    return Sample(toks, prompt_len, answer, sal)


def gen_task(task: str, seed: int, max_seq: int) -> Sample:
    """Paper-workload presets, sized to fit ``max_seq`` (incl. answer)."""
    if task == "gsm":
        # long body, queried fact anywhere, question at the very end;
        # sized so BOS + 4*pairs + 2*filler + 3 (query) + 2 (answer) <= max_seq
        cap_pairs = max(3, min(16, (max_seq - 8) // 8))
        n_pairs = 3 + SplitMix64(seed ^ 0xA5).below(cap_pairs - 2)
        budget = (max_seq - 6 - 4 * n_pairs) // 2
        want = 1 + SplitMix64(seed ^ 0x5A).below(max(1, budget))
        n_filler = max(0, min(budget, want))
        return gen_recall(seed, n_pairs, n_filler)
    if task == "code":
        # short-prompt regime (Table B): few pairs, no filler
        n_pairs = 4 + SplitMix64(seed ^ 0xC0).below(5)  # 4..8
        return gen_recall(seed, n_pairs, n_filler=2)
    if task.startswith("lines"):
        n_lines = int(task[len("lines"):])
        return gen_line_retrieval(seed, n_lines)
    raise ValueError(f"unknown task {task!r}")


def pad_batch(samples: List[Sample], max_seq: int, full_loss: bool = False):
    """-> (tokens [B,S], targets [B,S], loss_mask [B,S]) python lists.

    ``full_loss=False`` restricts the next-token loss to the answer span
    (the eval objective).  ``full_loss=True`` trains on every non-PAD
    position — much denser gradient signal, which is what actually makes
    the induction/recall circuit form (random body tokens contribute an
    irreducible-entropy floor but useful structure gradients).
    """
    B = len(samples)
    toks = [[PAD] * max_seq for _ in range(B)]
    tgts = [[PAD] * max_seq for _ in range(B)]
    mask = [[0.0] * max_seq for _ in range(B)]
    for b, s in enumerate(samples):
        seq = s.tokens[:max_seq]
        for i, t in enumerate(seq):
            toks[b][i] = t
        for i in range(len(seq) - 1):
            tgts[b][i] = seq[i + 1]
            if full_loss or i + 1 >= s.prompt_len:
                mask[b][i] = 1.0
    return toks, tgts, mask


def with_extra_queries(sample: Sample, n_extra: int, seed: int,
                       max_seq: int) -> Sample:
    """Training augmentation: append extra `QUERY key SEP val NL` blocks
    re-querying random body pairs.  Each block is another recall
    opportunity, multiplying the per-sequence learning signal.  Serving/eval
    always uses the plain single-query layout.
    """
    # collect (key, val) pairs present in the body
    pairs = []
    t = sample.tokens
    for i in range(len(t) - 2):
        if KEY0 <= t[i] < KEY0 + NKEY and t[i + 1] == SEP and \
                VAL0 <= t[i + 2] < VAL0 + NVAL:
            pairs.append((t[i], t[i + 2]))
    if not pairs:
        return sample
    rng = SplitMix64(seed ^ 0xEE)
    toks = list(sample.tokens)
    for _ in range(n_extra):
        if len(toks) + 4 > max_seq:
            break
        k, v = pairs[rng.below(len(pairs))]
        toks.extend([QUERY, k, SEP, v])
    return Sample(toks, sample.prompt_len, sample.answer, sample.salient_span)


def train_sample(rng: SplitMix64, max_seq: int) -> Sample:
    """Training mixture covering all three serve-time layouts."""
    r = rng.below(100)
    seed = rng.next_u64()
    if r < 40:
        s = gen_task("gsm", seed, max_seq)
    elif r < 70:
        cap = max(2, min(36, (max_seq - 6) // 6 - 1))
        n_lines = 2 + SplitMix64(seed ^ 0x11).below(cap - 1)
        s = gen_line_retrieval(seed, n_lines)
    else:
        s = gen_task("code", seed, max_seq)
    assert fits(s, max_seq), (r, len(s.tokens), max_seq)
    return s
