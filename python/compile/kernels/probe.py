"""Probe-token attention + fused normalized-saliency kernel (paper §4.3).

The efficient approximation of Eq. (8): only ``p`` probe rows of the
attention matrix are computed through standard attention (Eq. 9); the
columnwise normalized reduction that yields per-token saliency is fused
into the same kernel, so the [p, lk] probe score matrix never leaves VMEM
when p is small (p = 10% of l in the paper's config).

Grid: one program per key block of width Bk — each program computes the
[p, Bk] probe-score stripe and reduces it to a [Bk] saliency stripe.  The
softmax over the key dimension needs row statistics across stripes, so the
row max / row sum are computed by a cheap [p, lk] pre-pass (still O(p·l),
not O(l²)) lowered into the same HLO module.

Runs with ``interpret=True`` (CPU PJRT mandate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
NEG_INF = -1e30


def _pick_block(l: int, want: int) -> int:
    b = min(want, l)
    while l % b != 0:
        b -= 1
    return b


def _probe_kernel(qp_ref, k_ref, rowstat_ref, pidx_ref, a_ref, sal_ref, *,
                  bk: int, offs: int, scale: float, causal: bool):
    """One key stripe: probe scores [p, bk] + normalized saliency [bk]."""
    j = pl.program_id(0)
    qp = qp_ref[...]            # [p, d]
    k = k_ref[...]              # [bk, d] — this stripe's keys
    rmax = rowstat_ref[0:1, :].T  # [p, 1]
    rsum = rowstat_ref[1:2, :].T  # [p, 1]
    pidx = pidx_ref[...]        # [1, p] int32 probe positions (query-frame)

    s = jnp.dot(qp, k.T, preferred_element_type=jnp.float32) * scale  # [p, bk]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if causal:
        cover = kpos <= (pidx.T + offs)  # [p, bk]
        s = jnp.where(cover, s, NEG_INF)
    else:
        cover = jnp.ones_like(s, dtype=bool)
    a = jnp.exp(s - rmax) / rsum  # softmax completed with global row stats
    a = jnp.where(cover, a, 0.0)
    a_ref[...] = a.astype(a_ref.dtype)

    # Eq. (8) restricted to probe rows: per-column sum / per-column coverage.
    nnz = jnp.maximum(jnp.sum(cover.astype(jnp.float32), axis=0), 1.0)  # [bk]
    sal_ref[...] = (jnp.sum(a, axis=0) / nnz).astype(sal_ref.dtype)[None, :]


def probe_attention_saliency(
    q: jnp.ndarray,
    k: jnp.ndarray,
    probe_idx: jnp.ndarray,
    causal: bool = True,
    block_k: int = 128,
):
    """Probe scores (Eq. 9) + approximate normalized saliency (Eq. 8).

    Args:
      q: [lq, d] query states (full — probe rows are gathered inside).
      k: [lk, d] key states.
      probe_idx: [p] int32 indices into the query sequence.
      causal: apply the causal mask (probe row i covers keys [0, offs+i]).

    Returns:
      (a_probe [p, lk], saliency [lk]).
    """
    lq, d = q.shape
    lk = k.shape[0]
    p = probe_idx.shape[0]
    offs = lk - lq
    scale = 1.0 / (d**0.5)

    qp = q[probe_idx]  # [p, d]

    # Row-stat pre-pass: O(p·lk) — the whole point is p << lq.
    s_full = (qp @ k.T) * scale
    if causal:
        kpos = jnp.arange(lk)[None, :]
        cover = kpos <= (probe_idx[:, None] + offs)
        s_full = jnp.where(cover, s_full, NEG_INF)
    rmax = jnp.max(s_full, axis=-1)               # [p]
    rsum = jnp.sum(jnp.exp(s_full - rmax[:, None]), axis=-1)  # [p]
    rowstat = jnp.stack([rmax, rsum])             # [2, p]

    bk = _pick_block(lk, block_k)
    kernel = functools.partial(
        _probe_kernel, bk=bk, offs=offs, scale=scale, causal=causal
    )
    a_probe, sal = pl.pallas_call(
        kernel,
        grid=(lk // bk,),
        in_specs=[
            pl.BlockSpec((p, d), lambda j: (0, 0)),
            pl.BlockSpec((bk, d), lambda j: (j, 0)),
            pl.BlockSpec((2, p), lambda j: (0, 0)),
            pl.BlockSpec((1, p), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((p, bk), lambda j: (0, j)),
            pl.BlockSpec((1, bk), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, lk), jnp.float32),
            jax.ShapeDtypeStruct((1, lk), jnp.float32),
        ],
        interpret=INTERPRET,
    )(qp, k, rowstat, probe_idx.astype(jnp.int32)[None, :])
    return a_probe, sal[0]


def select_probe_indices(
    l: int,
    ratio_recent: float = 0.05,
    ratio_random: float = 0.05,
    seed: int = 0,
) -> jnp.ndarray:
    """The paper's hybrid random+recent probe strategy (§4.3, Table 2).

    Returns sorted unique indices: the trailing ``ratio_recent`` of the
    sequence plus ``ratio_random`` sampled uniformly from the remainder.
    """
    n_recent = max(1, int(round(l * ratio_recent)))
    n_random = max(1, int(round(l * ratio_random)))
    recent = jnp.arange(l - n_recent, l)
    pool = jnp.arange(0, l - n_recent)
    key = jax.random.PRNGKey(seed)
    rand = jax.random.choice(key, pool, shape=(min(n_random, pool.shape[0]),),
                             replace=False)
    return jnp.sort(jnp.concatenate([rand, recent])).astype(jnp.int32)
