"""Pallas quantization kernels for the KV cache (paper §4.1, Alg. 1).

Four granularities are implemented, matching Table 1 of the paper:

* :func:`token_quant`   — one (s, z) per token row (baseline)
* :func:`channel_quant` — one (s, z) per channel column (used for keys)
* :func:`group_quant`   — one (s, z) per ``group`` channels per token
* :func:`cst_quant`     — channel-separable tokenwise quantization (Alg. 1,
  used for values): channel normalization by ``sqrt(max|X_i|)`` (Eq. 6),
  tokenwise quantization (Eq. 5), channel rescale.

All kernels are fake-quant (quantize -> dequantize) so they can be fused
straight into the L2 attention graph; the *bit-packed* storage form lives in
the Rust KV-cache manager (``rust/src/kvcache``), which must agree bit-for-
bit with the grid semantics here (checked by cross-layer tests).

TPU mapping: each grid step owns a ``(block_l, hd)`` token slab in VMEM; the
channel statistics for CST are computed in a separate single-pass reduction
kernel so the token slabs never need cross-block communication.  All kernels
run with ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-PJRT mandate; see module docstring.


def _pick_block(l: int, want: int = 128) -> int:
    """Largest divisor of ``l`` not exceeding ``want`` (grid must tile l)."""
    b = min(want, l)
    while l % b != 0:
        b -= 1
    return b

def _qparams(xmin, xmax, qmax):
    """Shared (s, z) derivation (Eq. 5) with the exact-constant degenerate
    convention (must match ref.uniform_quant and rust QuantParams)."""
    s = (xmax - xmin) / qmax
    deg = s <= 0.0
    s_deg = jnp.where(jnp.abs(xmin) > 0.0, jnp.abs(xmin), 1.0)
    s = jnp.where(deg, s_deg, s)
    z = jnp.where(deg, jnp.where(xmin < 0.0, 1.0, 0.0), -jnp.round(xmin / s))
    return s, z



# ---------------------------------------------------------------------------
# Tokenwise fake-quant kernel
# ---------------------------------------------------------------------------


def _token_quant_kernel(x_ref, o_ref, *, qmax: float):
    x = x_ref[...]
    xmin = jnp.min(x, axis=-1, keepdims=True)
    xmax = jnp.max(x, axis=-1, keepdims=True)
    s, z = _qparams(xmin, xmax, qmax)
    q = jnp.clip(jnp.round(x / s) + z, 0.0, qmax)
    o_ref[...] = (q - z) * s


def token_quant(x: jnp.ndarray, bits: int, block_l: int = 128) -> jnp.ndarray:
    """Tokenwise fake-quant of ``x: [l, hd]`` to ``bits``.

    Grid is over token blocks: each program quantizes ``block_l`` full rows,
    so the per-token (s, z) never crosses a block boundary.
    """
    l, hd = x.shape
    bl = _pick_block(l, block_l)
    return pl.pallas_call(
        functools.partial(_token_quant_kernel, qmax=2.0**bits - 1.0),
        grid=(l // bl,),
        in_specs=[pl.BlockSpec((bl, hd), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bl, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, hd), x.dtype),
        interpret=INTERPRET,
    )(x)


# ---------------------------------------------------------------------------
# Channelwise fake-quant kernel (keys)
# ---------------------------------------------------------------------------


def _channel_quant_kernel(x_ref, stats_ref, o_ref, *, qmax: float):
    x = x_ref[...]
    xmin = stats_ref[0:1, :]
    xmax = stats_ref[1:2, :]
    s, z = _qparams(xmin, xmax, qmax)
    q = jnp.clip(jnp.round(x / s) + z, 0.0, qmax)
    o_ref[...] = (q - z) * s


def channel_quant(x: jnp.ndarray, bits: int, block_l: int = 128) -> jnp.ndarray:
    """Channelwise fake-quant of ``x: [l, hd]`` to ``bits``.

    Channel (min, max) are a global reduction, so they are computed once
    outside the grid (they lower into the same HLO module) and broadcast to
    every token block — this is the TPU-friendly split: one tiny reduction
    pass, then embarrassingly parallel slabs.
    """
    l, hd = x.shape
    stats = jnp.stack([jnp.min(x, axis=0), jnp.max(x, axis=0)])  # [2, hd]
    bl = _pick_block(l, block_l)
    return pl.pallas_call(
        functools.partial(_channel_quant_kernel, qmax=2.0**bits - 1.0),
        grid=(l // bl,),
        in_specs=[
            pl.BlockSpec((bl, hd), lambda i: (i, 0)),
            pl.BlockSpec((2, hd), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bl, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, hd), x.dtype),
        interpret=INTERPRET,
    )(x, stats)


# ---------------------------------------------------------------------------
# Groupwise fake-quant kernel (Table 1 baseline)
# ---------------------------------------------------------------------------


def _group_quant_kernel(x_ref, o_ref, *, qmax: float, group: int):
    x = x_ref[...]
    bl, hd = x.shape
    xg = x.reshape(bl, hd // group, group)
    xmin = jnp.min(xg, axis=-1, keepdims=True)
    xmax = jnp.max(xg, axis=-1, keepdims=True)
    s, z = _qparams(xmin, xmax, qmax)
    q = jnp.clip(jnp.round(xg / s) + z, 0.0, qmax)
    o_ref[...] = ((q - z) * s).reshape(bl, hd)


def group_quant(
    x: jnp.ndarray, bits: int, group: int = 32, block_l: int = 128
) -> jnp.ndarray:
    """Groupwise fake-quant: one (s, z) per ``group`` channels per token."""
    l, hd = x.shape
    assert hd % group == 0, f"hd={hd} % group={group} != 0"
    bl = _pick_block(l, block_l)
    return pl.pallas_call(
        functools.partial(_group_quant_kernel, qmax=2.0**bits - 1.0, group=group),
        grid=(l // bl,),
        in_specs=[pl.BlockSpec((bl, hd), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bl, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, hd), x.dtype),
        interpret=INTERPRET,
    )(x)


# ---------------------------------------------------------------------------
# Channel-separable tokenwise quantization (Alg. 1) — the paper's scheme
# ---------------------------------------------------------------------------


def _cst_quant_kernel(x_ref, c_ref, o_ref, *, qmax: float):
    x = x_ref[...]
    c = c_ref[...]  # [1, hd] channel scales, sqrt(max|X_i|)
    xn = x / c
    xmin = jnp.min(xn, axis=-1, keepdims=True)
    xmax = jnp.max(xn, axis=-1, keepdims=True)
    s, z = _qparams(xmin, xmax, qmax)
    q = jnp.clip(jnp.round(xn / s) + z, 0.0, qmax)
    o_ref[...] = ((q - z) * s) * c


def cst_quant(x: jnp.ndarray, bits: int, block_l: int = 128) -> jnp.ndarray:
    """Alg. 1 (CSTQuant) as a Pallas kernel over ``x: [l, hd]``.

    The channel scale vector ``c = sqrt(max|X_i|)`` (Eq. 6) is a one-pass
    global reduction; normalize -> tokenwise-quant -> rescale all happen
    inside one VMEM-resident slab per grid step, so the data is read from
    HBM exactly once for the quantization proper.
    """
    l, hd = x.shape
    c = jnp.sqrt(jnp.max(jnp.abs(x), axis=0, keepdims=True))  # [1, hd]
    c = jnp.where(c <= 0.0, 1.0, c)
    bl = _pick_block(l, block_l)
    return pl.pallas_call(
        functools.partial(_cst_quant_kernel, qmax=2.0**bits - 1.0),
        grid=(l // bl,),
        in_specs=[
            pl.BlockSpec((bl, hd), lambda i: (i, 0)),
            pl.BlockSpec((1, hd), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bl, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, hd), x.dtype),
        interpret=INTERPRET,
    )(x, c)


# ---------------------------------------------------------------------------
# Mixed-precision KV compression (ZipCache quantization config)
# ---------------------------------------------------------------------------


def zipcache_quant_kv(
    k: jnp.ndarray,
    v: jnp.ndarray,
    salient_mask: jnp.ndarray,
    bits_high: int = 4,
    bits_low: int = 2,
):
    """Quantize (K, V) with the paper's mixed-precision config (§5.1).

    Keys: channelwise quantization. Values: CSTQuant.  ``salient_mask``
    ([l] bool) selects which tokens get ``bits_high``; the rest get
    ``bits_low``.  Fake-quant both ways and select per token — this is the
    lowering-friendly formulation (no data-dependent shapes), and is exactly
    what the Rust cache manager does physically with two packed pools.
    """
    m = salient_mask[:, None]
    k_hi = channel_quant(k, bits_high)
    k_lo = channel_quant(k, bits_low)
    v_hi = cst_quant(v, bits_high)
    v_lo = cst_quant(v, bits_low)
    k_q = jnp.where(m, k_hi, k_lo)
    v_q = jnp.where(m, v_hi, v_lo)
    return k_q, v_q
