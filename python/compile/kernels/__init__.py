"""Layer-1 Pallas kernels for ZipCache (interpret-mode; CPU-PJRT safe).

Public surface:
  * quantization — :mod:`.cstquant` (token/channel/group/CST fake-quant,
    mixed-precision ``zipcache_quant_kv``)
  * attention    — :mod:`.flash` (tiled online-softmax FlashAttention)
  * saliency     — :mod:`.probe` (probe attention + normalized saliency)
  * oracles      — :mod:`.ref` (pure-jnp references, the pytest ground truth)
"""

from . import ref  # noqa: F401
from .cstquant import (  # noqa: F401
    channel_quant,
    cst_quant,
    group_quant,
    token_quant,
    zipcache_quant_kv,
)
from .flash import flash_attention, flash_attention_mha  # noqa: F401
from .probe import probe_attention_saliency, select_probe_indices  # noqa: F401
