"""Tiled online-softmax attention (FlashAttention) as a Pallas kernel.

This is the fast path of the paper's §4.3: the attention output for ALL
tokens is computed in (Bq, Bk) tiles with online softmax, never
materializing the l×l score matrix — O(l) memory instead of O(l²)
(paper Fig. 4(c)).  Saliency for the probe subset is handled by the
separate ``probe.py`` kernel so this kernel stays score-free.

TPU mapping (DESIGN.md §3):
  * grid = (l / Bq,): each program owns one Q tile resident in VMEM
    (threadblock analogue).
  * the K/V tiles are streamed through VMEM by a fori_loop — this loop IS
    the HBM↔VMEM schedule FlashAttention expresses with threadblocks.
  * ``q_tile @ k_tile.T`` is the MXU contraction; Bq/Bk default to 128 to
    match the 128×128 systolic array.

VMEM footprint per program (f32): Bq·d (Q) + 2·Bk·d (K,V tile) + Bq·Bk
(scores) + Bq·d (accum) + O(Bq) stats.  For Bq=Bk=128, d=128 that is
~0.33 MB — far under the ~16 MB VMEM budget, leaving room for
double-buffering the K/V stream.

Runs with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

NEG_INF = -1e30  # finite -inf stand-in: keeps 0*inf NaNs out of the masked path


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, lk: int,
                  causal: bool, offs: int, scale: float):
    """One Q tile vs the full K/V stream, online softmax."""
    qi = pl.program_id(0)
    q = q_ref[...]  # [bq, d]
    d = q.shape[-1]

    m0 = jnp.full((bq, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)

    nkb = lk // bk
    if causal:
        # Key blocks strictly above this Q tile's causal frontier contribute
        # nothing; skip them (dynamic fori_loop bound lowers to while_loop).
        # Frontier key index for this tile = offs + (qi+1)*bq - 1.
        nkb_eff = jnp.minimum((offs + (qi + 1) * bq + bk - 1) // bk, nkb)
        nkb_eff = jnp.maximum(nkb_eff, 1)
    else:
        nkb_eff = nkb

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None)))  # [bk, d]
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos + offs, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        return m_cur, l_cur, acc

    m, l, acc = jax.lax.fori_loop(0, nkb_eff, body, (m0, l0, acc0))
    l = jnp.where(l <= 0.0, 1.0, l)  # fully-masked rows (shouldn't occur causally)
    o_ref[...] = (acc / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """FlashAttention over ``q: [lq, d]``, ``k, v: [lk, d]`` -> ``[lq, d]``.

    Supports decode-style ``lq < lk``: query row i attends to keys
    ``[0, lk - lq + i]`` (rows aligned to the end of the key sequence),
    matching :func:`ref.standard_attention`.
    """
    lq, d = q.shape
    lk = k.shape[0]
    bq = _pick_block(lq, block_q)
    bk = _pick_block(lk, block_k)
    offs = lk - lq
    scale = 1.0 / (d**0.5)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, lk=lk, causal=causal, offs=offs, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(lq // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((lk, d), lambda i: (0, 0)),  # streamed inside kernel
            pl.BlockSpec((lk, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lq, d), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


def _pick_block(l: int, want: int) -> int:
    b = min(want, l)
    while l % b != 0:
        b -= 1
    return b


def flash_attention_mha(q, k, v, causal: bool = True, **kw) -> jnp.ndarray:
    """Vmapped multi-head wrapper: q,k,v: [h, l, d] -> [h, l, d]."""
    return jax.vmap(lambda qh, kh, vh: flash_attention(qh, kh, vh, causal, **kw))(
        q, k, v
    )
