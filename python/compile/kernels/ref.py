"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each Pallas kernel in
``cstquant.py`` / ``flash.py`` / ``probe.py`` is checked against the
functions here via pytest (``python/tests/``).  Everything is written in
plain ``jax.numpy`` with no tiling tricks so the math is auditable against
the paper's equations:

* Eq. (5)  — uniform quantization  -> :func:`uniform_quant`
* Eq. (6)  — channel normalization -> :func:`cst_quant`
* Eq. (7)  — accumulated scores    -> :func:`accumulated_saliency`
* Eq. (8)  — normalized scores     -> :func:`normalized_saliency`
* Eq. (9)  — probe attention       -> :func:`probe_attention`
* Alg. (1) — CSTQuant              -> :func:`cst_quant`
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Quantization references (paper §3.2, §4.1, Alg. 1)
# ---------------------------------------------------------------------------


def uniform_quant(x: jnp.ndarray, bits: int, axis=None):
    """Eq. (5): uniform asymmetric fake-quantization of ``x`` to ``bits``.

    ``axis`` selects the reduction axes over which one (scale, zero) pair is
    shared; ``None`` means a single pair for the whole tensor.  Returns the
    dequantized tensor (fake-quant), matching how the kernels are verified.
    """
    qmax = 2.0**bits - 1.0
    xmin = jnp.min(x, axis=axis, keepdims=True)
    xmax = jnp.max(x, axis=axis, keepdims=True)
    s = (xmax - xmin) / qmax
    # Degenerate (constant) slices: choose (s, z) so the constant value
    # round-trips exactly: s = |c| (or 1 for c = 0), z = 1 if c < 0 else 0.
    deg = s <= 0.0
    s_deg = jnp.where(jnp.abs(xmin) > 0.0, jnp.abs(xmin), 1.0)
    s = jnp.where(deg, s_deg, s)
    z = jnp.where(deg, jnp.where(xmin < 0.0, 1.0, 0.0), -jnp.round(xmin / s))
    q = jnp.clip(jnp.round(x / s) + z, 0.0, qmax)
    return (q - z) * s


def token_quant(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Tokenwise quantization: one (s, z) per token row. x: [l, hd]."""
    return uniform_quant(x, bits, axis=-1)


def channel_quant(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Channelwise quantization: one (s, z) per channel column. x: [l, hd]."""
    return uniform_quant(x, bits, axis=-2)


def group_quant(x: jnp.ndarray, bits: int, group: int = 32) -> jnp.ndarray:
    """Groupwise quantization: one (s, z) per ``group`` contiguous channels
    within each token (KIVI-style fine granularity). x: [l, hd]."""
    l, hd = x.shape
    assert hd % group == 0, f"hd={hd} not divisible by group={group}"
    xg = x.reshape(l, hd // group, group)
    return uniform_quant(xg, bits, axis=-1).reshape(l, hd)


def cst_quant(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Alg. 1 (CSTQuant): channel-separable tokenwise quantization.

    1. normalize each channel i by c_i = sqrt(max|X_i|)      (Eq. 6)
    2. tokenwise uniform quantization of the normalized data (Eq. 5)
    3. rescale channels back by c_i
    """
    c = jnp.sqrt(jnp.max(jnp.abs(x), axis=-2, keepdims=True))
    c = jnp.where(c <= 0.0, 1.0, c)
    xn = x / c
    xq = token_quant(xn, bits)
    return xq * c


# ---------------------------------------------------------------------------
# Attention references (paper §3.1, §4.2, §4.3)
# ---------------------------------------------------------------------------


def causal_mask(l: int) -> jnp.ndarray:
    return jnp.tril(jnp.ones((l, l), dtype=bool))


def standard_attention(q, k, v, causal: bool = True):
    """Eq. (2): full-matrix softmax attention. q,k,v: [l, d] -> (out, A)."""
    lq, d = q.shape
    lk = k.shape[0]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        # Rows are aligned to the *end* of the key sequence so decode-style
        # lq < lk works: query row i attends to keys [0, lk - lq + i].
        offs = lk - lq
        mask = jnp.arange(lk)[None, :] <= (jnp.arange(lq)[:, None] + offs)
        scores = jnp.where(mask, scores, -jnp.inf)
    a = jax.nn.softmax(scores, axis=-1)
    return a @ v, a


def flash_attention(q, k, v, causal: bool = True):
    """Reference output of the tiled kernel == standard attention output."""
    out, _ = standard_attention(q, k, v, causal)
    return out


def accumulated_saliency(a: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7): p_i = sum_k A[k, i] (H2O / MiKV metric)."""
    return jnp.sum(a, axis=0)


def normalized_saliency(a: jnp.ndarray, causal: bool = True) -> jnp.ndarray:
    """Eq. (8): p̃_i = sum_k A[k, i] / nnz(A[:, i]).

    For a causal [l, l] matrix nnz(A[:, i]) = l - i.  We compute nnz from the
    mask structure rather than counting exact zeros so that numerically tiny
    attention values still count as "present", matching the paper's intent.
    """
    lq, lk = a.shape
    if causal:
        offs = lk - lq
        mask = jnp.arange(lk)[None, :] <= (jnp.arange(lq)[:, None] + offs)
        nnz = jnp.sum(mask, axis=0)
    else:
        nnz = jnp.full((lk,), lq)
    nnz = jnp.maximum(nnz, 1)
    return jnp.sum(a, axis=0) / nnz


def probe_attention(q, k, probe_idx, causal: bool = True):
    """Eq. (9): attention scores of probe tokens only. Returns [p, lk]."""
    d = q.shape[-1]
    qp = q[probe_idx]
    scores = (qp @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        lk = k.shape[0]
        offs = lk - q.shape[0]
        mask = jnp.arange(lk)[None, :] <= (probe_idx[:, None] + offs)
        scores = jnp.where(mask, scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1)


def probe_saliency(q, k, probe_idx, causal: bool = True):
    """Approximate Eq. (8) from probe rows only (paper §4.3).

    nnz per column is the number of probe rows whose causal span covers that
    column, i.e. the count of probe_idx >= column position (shifted by the
    query/key offset).
    """
    a = probe_attention(q, k, probe_idx, causal)
    lk = k.shape[0]
    if causal:
        offs = lk - q.shape[0]
        cover = (probe_idx[:, None] + offs) >= jnp.arange(lk)[None, :]
        nnz = jnp.sum(cover, axis=0)
    else:
        nnz = jnp.full((lk,), probe_idx.shape[0])
    nnz = jnp.maximum(nnz, 1)
    return jnp.sum(a, axis=0) / nnz
