"""ZipCache compile path: L1 Pallas kernels + L2 JAX model + AOT lowering.

Build-time only — nothing in this package is imported at serving time.
``python -m compile.aot`` produces ``artifacts/*.hlo.txt`` + manifest that
the Rust runtime (``rust/src/runtime``) loads via PJRT.
"""
