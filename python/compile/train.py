"""Build-time training of the substrate model on the synthetic recall corpus.

``python -m compile.train --config tiny --steps 1500`` produces
``artifacts/params_<config>.npz``.  This replaces the paper's pretrained
LLaMA/Mistral checkpoints (DESIGN.md §2): the resulting model genuinely
solves the retrieval-style workloads through attention, which is the
property the paper's saliency analysis depends on.

Training uses plain Adam and the cheap standard-attention loss path
(``model.loss_fn``); the Pallas kernels only enter the *serving* graphs,
whose equivalence to the standard path is covered by the kernel tests.
The loss curve is appended to ``artifacts/train_log_<config>.json`` and
summarized in EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .model import CONFIGS, init_params, loss_fn


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps),
        params, m, v,
    )
    return params, {"m": m, "v": v, "t": t}


def make_batch(rng: D.SplitMix64, batch: int, max_seq: int):
    """Training batches: multi-query augmented samples + full-position loss
    (dense recall signal; see data.with_extra_queries)."""
    samples = []
    for _ in range(batch):
        s = D.train_sample(rng, max_seq)
        s = D.with_extra_queries(s, n_extra=6, seed=rng.next_u64(), max_seq=max_seq)
        samples.append(s)
    toks, tgts, mask = D.pad_batch(samples, max_seq, full_loss=True)
    return (
        jnp.asarray(toks, jnp.int32),
        jnp.asarray(tgts, jnp.int32),
        jnp.asarray(mask, jnp.float32),
    )


def answer_accuracy(params, cfg, rng: D.SplitMix64, n: int = 64) -> float:
    """Greedy answer-token accuracy on held-out samples (teacher-forced
    prompt, single-step answer prediction)."""
    samples = [D.train_sample(rng, cfg.max_seq) for _ in range(n)]
    toks, _, _ = D.pad_batch(samples, cfg.max_seq)
    toks = jnp.asarray(toks, jnp.int32)

    @jax.jit
    def logits_of(batch_tokens):
        def single(tok):
            S = cfg.max_seq
            positions = jnp.arange(S, dtype=jnp.int32)
            from .model import (_masked_standard_attention, _merge_heads,
                                _qkv, rmsnorm, swiglu)
            x = params["embed"][tok]
            ones = jnp.ones((S,), jnp.float32)
            for layer in params["layers"]:
                q, k, v = _qkv(x, layer, cfg, positions)
                o, _ = _masked_standard_attention(q, k, v, ones)
                x = x + _merge_heads(o, cfg) @ layer["wo"]
                x = x + swiglu(rmsnorm(x, layer["mlp_norm"]), layer)
            return rmsnorm(x, params["final_norm"]) @ params["embed"].T
        return jax.vmap(single)(batch_tokens)

    lg = np.asarray(logits_of(toks))
    hit = 0
    for i, s in enumerate(samples):
        pred = int(lg[i, s.prompt_len - 1].argmax())
        hit += int(pred == s.answer[0])
    return hit / n


def flatten_params(params):
    flat, treedef = jax.tree_util.tree_flatten(params)
    return flat, treedef


def save_params(params, path: str):
    flat, _ = jax.tree_util.tree_flatten(params)
    np.savez(path, *[np.asarray(x) for x in flat])


def load_params(cfg, path: str):
    """Rebuild the params pytree from npz using the init tree structure."""
    template = init_params(cfg)
    flat, treedef = jax.tree_util.tree_flatten(template)
    with np.load(path) as z:
        arrs = [z[f"arr_{i}"] for i in range(len(flat))]
    assert len(arrs) == len(flat)
    for a, t in zip(arrs, flat):
        assert a.shape == t.shape, f"{a.shape} != {t.shape}"
    return jax.tree_util.tree_unflatten(treedef, [jnp.asarray(a) for a in arrs])


def train(config: str, steps: int, batch: int, lr: float, seed: int,
          out_dir: str, target_acc: float = 0.97) -> str:
    cfg = CONFIGS[config]
    params = init_params(cfg, seed=seed)
    opt = adam_init(params)
    rng = D.SplitMix64(seed * 7919 + 13)

    @jax.jit
    def step(params, opt, toks, tgts, mask, lr_t):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, toks, tgts, mask)
        params, opt = adam_update(params, grads, opt, lr_t)
        return params, opt, loss

    def lr_at(i: int) -> float:
        """Linear warmup (50 steps) -> cosine decay to 10%."""
        import math
        if i < 50:
            return lr * (i + 1) / 50
        t = (i - 50) / max(1, steps - 50)
        return lr * (0.1 + 0.9 * 0.5 * (1 + math.cos(math.pi * t)))

    log = []
    t0 = time.time()
    for i in range(steps):
        toks, tgts, mask = make_batch(rng, batch, cfg.max_seq)
        params, opt, loss = step(params, opt, toks, tgts, mask,
                                 jnp.float32(lr_at(i)))
        if i % 50 == 0 or i == steps - 1:
            l = float(loss)
            log.append({"step": i, "loss": l, "wall_s": time.time() - t0})
            print(f"[train:{config}] step {i:5d} loss {l:.4f} "
                  f"({(time.time()-t0):.0f}s)", flush=True)
            if i > 0 and i % 300 == 0:
                acc = answer_accuracy(params, cfg, D.SplitMix64(999))
                log.append({"step": i, "eval_acc": acc})
                print(f"[train:{config}]   eval acc {acc:.3f}", flush=True)
                if acc >= target_acc:
                    break

    acc = answer_accuracy(params, cfg, D.SplitMix64(4242), n=128)
    log.append({"final_acc": acc, "params": cfg.n_params})
    print(f"[train:{config}] final answer accuracy {acc:.3f} "
          f"({cfg.n_params/1e6:.2f}M params)", flush=True)

    os.makedirs(out_dir, exist_ok=True)
    ppath = os.path.join(out_dir, f"params_{config}.npz")
    save_params(params, ppath)
    with open(os.path.join(out_dir, f"train_log_{config}.json"), "w") as f:
        json.dump(log, f, indent=1)
    return ppath


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny", choices=sorted(CONFIGS))
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    train(args.config, args.steps, args.batch, args.lr, args.seed, args.out)


if __name__ == "__main__":
    main()
