"""Layer-2 JAX model: a from-scratch GPT-style decoder for ZipCache.

This is the substrate transformer the paper's method operates on (we cannot
ship LLaMA weights — see DESIGN.md §2).  Pure functional JAX, no flax:

  * RMSNorm, rotary position embeddings, SwiGLU MLP, tied LM head
  * multi-head causal attention with an explicit KV-cache interface
  * two prefill variants:
      - ``prefill_flash``: attention through the L1 Pallas FlashAttention
        kernel + probe-token normalized saliency (the ZipCache fast path,
        Alg. 2) — never materializes l×l scores.
      - ``prefill_full``: standard attention that returns full per-layer
        accumulated AND normalized saliency (Eqs. 7/8) — the baseline path
        used by MiKV/H2O and by Fig. 3/4 reproductions.
  * ``decode_step``: one-token decode against a fixed-capacity cache with a
    validity mask (supports eviction-style baselines), Alg. 3's consumer.

Everything here is lowered AOT by ``aot.py`` to HLO text; the Rust runtime
executes the artifacts and owns all serving-time control flow.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import flash
from .kernels import probe as probe_mod
from .kernels import ref as kref

Params = Dict[str, Any]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static hyper-parameters of the decoder (all shapes are AOT-static)."""

    name: str = "tiny"
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 384
    max_seq: int = 256
    rope_base: float = 10000.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding tied with the LM head)."""
        per_layer = (
            4 * self.d_model * self.d_model  # wq wk wv wo
            + 3 * self.d_model * self.d_ff  # swiglu w1 w3 w2
            + 2 * self.d_model  # two rmsnorm gains
        )
        return self.vocab * self.d_model + self.n_layers * per_layer + self.d_model


# Registry of configs the build produces artifacts for.
CONFIGS: Dict[str, ModelConfig] = {
    # Serving config used by the experiments: 256-token window.
    "tiny": ModelConfig(name="tiny", vocab=256, d_model=128, n_layers=2,
                        n_heads=4, d_ff=384, max_seq=256),
    # Fast-test config: small enough that interpret-mode pallas in pytest is
    # quick, big enough to exercise multi-block grids. vocab must cover the
    # shared token map (ids up to 217 — see data.py).
    "micro": ModelConfig(name="micro", vocab=256, d_model=64, n_layers=2,
                         n_heads=4, d_ff=192, max_seq=64),
    # Larger scale config (artifact build is opt-in: slower to lower and
    # the HLO text carries every weight as a printed constant).
    "base": ModelConfig(name="base", vocab=256, d_model=256, n_layers=4,
                        n_heads=8, d_ff=768, max_seq=512),
}


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Scaled-normal init; deterministic in (cfg, seed)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 1 + cfg.n_layers)

    def dense(k, fan_in, shape):
        return (jax.random.normal(k, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    params: Params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[1 + li], 8)
        d, f = cfg.d_model, cfg.d_ff
        params["layers"].append({
            "attn_norm": jnp.ones((d,), jnp.float32),
            "wq": dense(ks[0], d, (d, d)),
            "wk": dense(ks[1], d, (d, d)),
            "wv": dense(ks[2], d, (d, d)),
            "wo": dense(ks[3], d, (d, d)),
            "mlp_norm": jnp.ones((d,), jnp.float32),
            "w1": dense(ks[4], d, (d, f)),
            "w3": dense(ks[5], d, (d, f)),
            "w2": dense(ks[6], f, (f, d)),
        })
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope_angles(cfg: ModelConfig, positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for ``positions`` ([l] int32) -> each [l, d_head/2]."""
    dh = cfg.d_head
    inv = 1.0 / (cfg.rope_base ** (jnp.arange(0, dh, 2) / dh))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [h, l, dh]; rotate channel pairs by per-position angles."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    xr1 = x1 * cos[None] - x2 * sin[None]
    xr2 = x1 * sin[None] + x2 * cos[None]
    # Re-interleave.
    out = jnp.stack([xr1, xr2], axis=-1)
    return out.reshape(x.shape)


def _split_heads(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """[l, d_model] -> [h, l, d_head]"""
    l = x.shape[0]
    return x.reshape(l, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)


def _merge_heads(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """[h, l, d_head] -> [l, d_model]"""
    h, l, dh = x.shape
    return x.transpose(1, 0, 2).reshape(l, h * dh)


def swiglu(x: jnp.ndarray, layer: Params) -> jnp.ndarray:
    return (jax.nn.silu(x @ layer["w1"]) * (x @ layer["w3"])) @ layer["w2"]


def _qkv(x: jnp.ndarray, layer: Params, cfg: ModelConfig, positions: jnp.ndarray):
    """Project + split heads + RoPE. Returns q,k,v: [h, l, dh]."""
    xn = rmsnorm(x, layer["attn_norm"])
    q = _split_heads(xn @ layer["wq"], cfg)
    k = _split_heads(xn @ layer["wk"], cfg)
    v = _split_heads(xn @ layer["wv"], cfg)
    cos, sin = rope_angles(cfg, positions)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def _masked_standard_attention(q, k, v, valid):
    """Per-head standard attention with causal+validity mask.

    q,k,v: [h, l, dh]; valid: [l] (1.0 = real token). Returns (out, A) with
    A: [h, l, l].
    """
    h, l, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((l, l), bool))
    mask = causal[None] & (valid[None, None, :] > 0.5)
    s = jnp.where(mask, s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(mask, a, 0.0)  # rows of padded queries stay normalized junk-free
    return jnp.einsum("hqk,hkd->hqd", a, v), a


# ---------------------------------------------------------------------------
# Prefill — full-score path (baselines, Fig. 3/4) and flash+probe path
# ---------------------------------------------------------------------------


def prefill_full(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 valid: jnp.ndarray):
    """Standard-attention prefill that materializes all scores.

    Args:
      tokens: [S] int32 (padded to cfg.max_seq=S)
      valid:  [S] f32 mask, 1.0 for real tokens.

    Returns dict with logits [S, V], kcache/vcache [L, H, S, dh],
    acc_saliency / norm_saliency [L, S] (Eqs. 7/8 averaged over heads).
    """
    S = cfg.max_seq
    positions = jnp.arange(S, dtype=jnp.int32)
    x = params["embed"][tokens]
    kc, vc, acc_sal, norm_sal = [], [], [], []
    # Column nnz for Eq. 8 under causal+valid masking: column i is visible to
    # valid query rows k >= i -> nnz = (# valid rows) - i for valid columns.
    causal = jnp.tril(jnp.ones((S, S), bool))
    colmask = causal & (valid[None, :] > 0.5) & (valid[:, None] > 0.5)
    nnz = jnp.maximum(jnp.sum(colmask, axis=0).astype(jnp.float32), 1.0)
    for layer in params["layers"]:
        q, k, v = _qkv(x, layer, cfg, positions)
        o, a = _masked_standard_attention(q, k, v, valid)
        # head-mean saliency, masked to valid query rows
        a_q = a * valid[None, :, None]
        acc = jnp.mean(jnp.sum(a_q, axis=1), axis=0)          # Eq. 7, [S]
        nrm = jnp.mean(jnp.sum(a_q, axis=1) / nnz[None], axis=0)  # Eq. 8, [S]
        acc_sal.append(acc)
        norm_sal.append(nrm)
        kc.append(k)
        vc.append(v)
        x = x + _merge_heads(o, cfg) @ layer["wo"]
        x = x + swiglu(rmsnorm(x, layer["mlp_norm"]), layer)
    logits = rmsnorm(x, params["final_norm"]) @ params["embed"].T
    return {
        "logits": logits,
        "kcache": jnp.stack(kc),
        "vcache": jnp.stack(vc),
        "acc_saliency": jnp.stack(acc_sal),
        "norm_saliency": jnp.stack(norm_sal),
    }


def prefill_flash(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  valid: jnp.ndarray, probe_idx: jnp.ndarray):
    """ZipCache prefill (Alg. 2): FlashAttention for output, probe rows for
    saliency.  Never materializes the full score matrix.

    probe_idx: [P] int32 probe positions (chosen by the Rust coordinator:
    5% recent + 5% random of the valid region).

    Returns logits, caches and probe-approximated normalized saliency [L, S].
    """
    S = cfg.max_seq
    positions = jnp.arange(S, dtype=jnp.int32)
    x = params["embed"][tokens]
    kc, vc, sal = [], [], []
    for layer in params["layers"]:
        q, k, v = _qkv(x, layer, cfg, positions)
        # Padded tail is causally after every valid token, so it cannot
        # corrupt valid rows; flash path needs no validity mask here.
        o = jax.vmap(lambda qh, kh, vh: flash.flash_attention(qh, kh, vh))(q, k, v)
        # Probe saliency per head -> mean over heads. Mask padded columns.
        def head_sal(qh, kh):
            _, s = probe_mod.probe_attention_saliency(qh, kh, probe_idx)
            return s
        s = jnp.mean(jax.vmap(head_sal)(q, k), axis=0) * valid
        sal.append(s)
        kc.append(k)
        vc.append(v)
        x = x + _merge_heads(o, cfg) @ layer["wo"]
        x = x + swiglu(rmsnorm(x, layer["mlp_norm"]), layer)
    logits = rmsnorm(x, params["final_norm"]) @ params["embed"].T
    return {
        "logits": logits,
        "kcache": jnp.stack(kc),
        "vcache": jnp.stack(vc),
        "norm_saliency": jnp.stack(sal),
    }


# ---------------------------------------------------------------------------
# Decode — one token against a fixed-capacity (possibly fake-quantized) cache
# ---------------------------------------------------------------------------


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                pos: jnp.ndarray, kcache: jnp.ndarray, vcache: jnp.ndarray,
                valid: jnp.ndarray):
    """One decode step (Alg. 3 consumer).

    Args:
      token: [] int32 current token id.
      pos:   [] int32 its position (== number of tokens already cached).
      kcache/vcache: [L, H, S, dh] — S = cfg.max_seq capacity; entries at
        indices >= pos are ignored via ``valid``; entries may be
        fake-quantized / zeroed by the Rust cache manager.
      valid: [S] f32, 1.0 where a cached token exists AND is not evicted.

    Returns logits [V], k_new/v_new [L, H, dh], and probe attention row
    a_row [L, S] (head-mean) so the coordinator can maintain the streaming
    probe accumulator of Alg. 3.
    """
    S = cfg.max_seq
    x = params["embed"][token][None, :]  # [1, d]
    pos_arr = pos[None]
    k_new, v_new, a_rows = [], [], []
    kpos = jnp.arange(S, dtype=jnp.int32)
    for li, layer in enumerate(params["layers"]):
        q, k1, v1 = _qkv(x, layer, cfg, pos_arr)  # [h, 1, dh]
        # The new row is handled out-of-cache: attention runs over cached
        # entries (masked by valid & kpos<pos) plus the self term, and the
        # Rust coordinator writes k_new/v_new into slot `pos` afterwards.
        kc = kcache[li]
        vc = vcache[li]
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
        s_cache = jnp.einsum("hqd,hkd->hqk", q, kc)[:, 0, :] * scale  # [h, S]
        mask = (valid > 0.5) & (kpos < pos)
        s_cache = jnp.where(mask[None, :], s_cache, NEG_INF)
        s_self = jnp.einsum("hd,hd->h", q[:, 0], k1[:, 0]) * scale  # [h]
        m = jnp.maximum(jnp.max(s_cache, axis=-1), s_self)
        p_cache = jnp.exp(s_cache - m[:, None])
        p_self = jnp.exp(s_self - m)
        denom = jnp.sum(p_cache, axis=-1) + p_self
        a = p_cache / denom[:, None]  # [h, S] attention over cached tokens
        o = jnp.einsum("hk,hkd->hd", a, vc) + (p_self / denom)[:, None] * v1[:, 0]
        a_rows.append(jnp.mean(a, axis=0))  # [S]
        k_new.append(k1[:, 0])
        v_new.append(v1[:, 0])
        x = x + (o.reshape(1, -1) @ layer["wo"])
        x = x + swiglu(rmsnorm(x, layer["mlp_norm"]), layer)
    logits = (rmsnorm(x, params["final_norm"]) @ params["embed"].T)[0]
    return {
        "logits": logits,
        "k_new": jnp.stack(k_new),
        "v_new": jnp.stack(v_new),
        "a_row": jnp.stack(a_rows),
    }


# ---------------------------------------------------------------------------
# Training objective (used by train.py, not lowered to artifacts)
# ---------------------------------------------------------------------------


def loss_fn(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            targets: jnp.ndarray, loss_mask: jnp.ndarray) -> jnp.ndarray:
    """Masked next-token cross-entropy over a batch.

    tokens/targets/loss_mask: [B, S]. Uses the cheap standard-attention path
    (training never runs interpret-mode pallas; flash==standard is verified
    separately by the kernel tests).
    """

    def single(tok, tgt, msk):
        S = tok.shape[0]
        positions = jnp.arange(S, dtype=jnp.int32)
        x = params["embed"][tok]
        ones = jnp.ones((S,), jnp.float32)
        for layer in params["layers"]:
            q, k, v = _qkv(x, layer, cfg, positions)
            o, _ = _masked_standard_attention(q, k, v, ones)
            x = x + _merge_heads(o, cfg) @ layer["wo"]
            x = x + swiglu(rmsnorm(x, layer["mlp_norm"]), layer)
        logits = rmsnorm(x, params["final_norm"]) @ params["embed"].T
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
        return jnp.sum(nll * msk) / jnp.maximum(jnp.sum(msk), 1.0)

    return jnp.mean(jax.vmap(single)(tokens, targets, loss_mask))
