"""AOT lowering: JAX (L2) + Pallas (L1) graphs -> HLO text artifacts.

``python -m compile.aot --configs micro,tiny --out ../artifacts`` emits, per
model config:

  * ``prefill_flash_<cfg>.hlo.txt`` — ZipCache prefill (Alg. 2): Flash
    attention + probe saliency.  inputs: tokens[S] i32, valid[S] f32,
    probe_idx[P] i32.  outputs: logits[S,V], kcache[L,H,S,dh],
    vcache[L,H,S,dh], norm_saliency[L,S].
  * ``prefill_full_<cfg>.hlo.txt`` — baseline prefill materializing full
    scores.  inputs: tokens, valid.  outputs: logits, kcache, vcache,
    acc_saliency[L,S], norm_saliency[L,S].
  * ``decode_<cfg>.hlo.txt`` — one decode step (Alg. 3 consumer).  inputs:
    token[] i32, pos[] i32, kcache, vcache, valid[S] f32.  outputs:
    logits[V], k_new[L,H,dh], v_new[L,H,dh], a_row[L,S].
  * ``quant_kv_<cfg>.hlo.txt`` — mixed-precision fake-quant of a cache
    (keys channelwise, values CSTQuant; Alg. 2 compress step). inputs:
    kcache, vcache, salient_mask[S] f32, plus static (hi, lo) bits baked
    per variant. outputs: kq, vq.

Model parameters are baked into the HLO as constants (trained weights from
``artifacts/params_<cfg>.npz`` when present, else deterministic init), so
the Rust binary needs no weight marshalling — artifacts are self-contained.

Interchange is HLO **text** (never ``.serialize()``): jax >= 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.cstquant import channel_quant, cst_quant
from .model import CONFIGS, ModelConfig, decode_step, init_params, prefill_flash, prefill_full


def probe_count(cfg: ModelConfig) -> int:
    """Static probe-set size: 10% of the window (5% recent + 5% random)."""
    return max(2, cfg.max_seq // 10)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the baked model weights
    # must survive the text round-trip into the Rust runtime.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points(cfg: ModelConfig, params):
    """(name, fn, example_args, output_names) for each artifact of ``cfg``."""
    S, L, H, dh, V = (cfg.max_seq, cfg.n_layers, cfg.n_heads, cfg.d_head,
                      cfg.vocab)
    P = probe_count(cfg)
    cache_spec = _spec((L, H, S, dh), jnp.float32)

    def pf_flash(tokens, valid, probe_idx):
        r = prefill_flash(params, cfg, tokens, valid, probe_idx)
        return (r["logits"], r["kcache"], r["vcache"], r["norm_saliency"])

    def pf_full(tokens, valid):
        r = prefill_full(params, cfg, tokens, valid)
        return (r["logits"], r["kcache"], r["vcache"], r["acc_saliency"],
                r["norm_saliency"])

    def dec(token, pos, kcache, vcache, valid):
        r = decode_step(params, cfg, token, pos, kcache, vcache, valid)
        return (r["logits"], r["k_new"], r["v_new"], r["a_row"])

    def quant_kv(kcache, vcache, salient, hi, lo):
        # keys channelwise / values CSTQuant per head (paper §5.1); the
        # salient mask selects hi vs lo bits per token (fake-quant; the
        # bit-packed physical form lives in rust/src/kvcache).
        def one(kh, vh):
            k_hi = channel_quant(kh, hi)
            k_lo = channel_quant(kh, lo)
            v_hi = cst_quant(vh, hi)
            v_lo = cst_quant(vh, lo)
            m = salient[:, None]
            return jnp.where(m > 0.5, k_hi, k_lo), jnp.where(m > 0.5, v_hi, v_lo)
        kq, vq = jax.vmap(jax.vmap(one))(kcache, vcache)
        return (kq, vq)

    entries = [
        (
            f"prefill_flash_{cfg.name}",
            pf_flash,
            (_spec((S,), jnp.int32), _spec((S,), jnp.float32),
             _spec((P,), jnp.int32)),
            ["logits", "kcache", "vcache", "norm_saliency"],
        ),
        (
            f"prefill_full_{cfg.name}",
            pf_full,
            (_spec((S,), jnp.int32), _spec((S,), jnp.float32)),
            ["logits", "kcache", "vcache", "acc_saliency", "norm_saliency"],
        ),
        (
            f"decode_{cfg.name}",
            dec,
            (_spec((), jnp.int32), _spec((), jnp.int32), cache_spec,
             cache_spec, _spec((S,), jnp.float32)),
            ["logits", "k_new", "v_new", "a_row"],
        ),
        (
            f"quant_kv_{cfg.name}",
            functools.partial(quant_kv, hi=4, lo=2),
            (cache_spec, cache_spec, _spec((S,), jnp.float32)),
            ["kq", "vq"],
        ),
    ]
    return entries


def load_or_init_params(cfg: ModelConfig, out_dir: str):
    ppath = os.path.join(out_dir, f"params_{cfg.name}.npz")
    if os.path.exists(ppath):
        from .train import load_params
        print(f"[aot] using trained params {ppath}")
        return load_params(cfg, ppath), os.path.basename(ppath)
    print(f"[aot] WARNING: no trained params for '{cfg.name}', baking init")
    return init_params(cfg), None


def build_config(cfg: ModelConfig, out_dir: str, manifest: dict) -> None:
    params, ppath = load_or_init_params(cfg, out_dir)
    for name, fn, args, out_names in entry_points(cfg, params):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "config": cfg.name,
            "file": os.path.basename(path),
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
            "outputs": out_names,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"[aot] {name}: {len(text)/1e6:.2f} MB HLO text")
    manifest["configs"][cfg.name] = {
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "d_head": cfg.d_head, "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq, "probe_count": probe_count(cfg),
        "n_params": cfg.n_params, "trained": ppath,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default="micro,tiny")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"entries": {}, "configs": {}}
    for name in args.configs.split(","):
        build_config(CONFIGS[name], args.out, manifest)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
