//! Offline stub of the `xla` (xla-rs) PJRT surface used by zipcache
//! (DESIGN.md §6).
//!
//! The real dependency wraps `xla_extension` (PJRT CPU client + HLO
//! parsing), a native library that is not present in the offline build
//! environment.  This stub keeps the crate compiling and the host-side
//! data path fully functional:
//!
//! * [`Literal`] is a *real* host-tensor implementation — `vec1`,
//!   `reshape`, `array_shape`, `to_vec`, `to_tuple` all behave like the
//!   genuine literal type, so `runtime::tensor`'s marshalling layer and
//!   its unit tests work unchanged.
//! * The execution surface ([`HloModuleProto`], [`XlaComputation`],
//!   [`PjRtClient`], [`PjRtLoadedExecutable`]) typechecks identically but
//!   returns a clear [`Error`] at the first point a compiled artifact
//!   would be needed.  Integration tests that require built artifacts
//!   already skip when loading fails, so the stub degrades gracefully.
//!
//! Swapping the real `xla` crate back in is a one-line change in the root
//! `Cargo.toml` (replace the `vendor/xla` path dependency).

use std::fmt;
use std::path::Path;

/// Stub error: a message, `Debug`-printable like the real crate's error.
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Error {
            msg: format!(
                "offline xla stub: {what} requires the real xla_extension \
                 runtime (see DESIGN.md §6)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

type Result<T> = std::result::Result<T, Error>;

/// Element types mirrored from the real crate (only F32/S32 are produced
/// by this stub, but the full set keeps match arms realistic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Array shape of a non-tuple literal: dimensions + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal: an f32/i32 tensor or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    S32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

/// Rust scalar types this stub can marshal in and out of a [`Literal`].
pub trait NativeType: Copy {
    fn vec1(data: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal::F32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error {
                msg: format!("to_vec::<f32> on non-F32 literal {other:?}"),
            }),
        }
    }
}

impl NativeType for i32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal::S32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::S32 { data, .. } => Ok(data.clone()),
            other => Err(Error {
                msg: format!("to_vec::<i32> on non-S32 literal {other:?}"),
            }),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1(data)
    }

    fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::S32 { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match; `&[]`
    /// produces a scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        self.clone().into_reshape(dims)
    }

    /// By-value [`Literal::reshape`]: moves the payload instead of
    /// cloning it.  The `vec1` + `reshape` marshalling pair used to copy
    /// every input tensor twice; the decode hot path builds literals with
    /// `vec1` + `into_reshape` so the payload is copied exactly once
    /// (zipcache DESIGN.md §9).
    pub fn into_reshape(self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if self.len() as i64 != want {
            return Err(Error {
                msg: format!("reshape {} elements to {dims:?}", self.len()),
            });
        }
        match self {
            Literal::F32 { data, .. } => {
                Ok(Literal::F32 { data, dims: dims.to_vec() })
            }
            Literal::S32 { data, .. } => {
                Ok(Literal::S32 { data, dims: dims.to_vec() })
            }
            Literal::Tuple(_) => Err(Error { msg: "reshape on tuple literal".into() }),
        }
    }

    /// Shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::F32 { dims, .. } => {
                Ok(ArrayShape { dims: dims.clone(), ty: ElementType::F32 })
            }
            Literal::S32 { dims, .. } => {
                Ok(ArrayShape { dims: dims.clone(), ty: ElementType::S32 })
            }
            Literal::Tuple(_) => Err(Error { msg: "array_shape on tuple".into() }),
        }
    }

    /// Copy the elements out as a `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Err(Error { msg: format!("to_tuple on {other:?}") }),
        }
    }
}

/// Parsed HLO module (stub: cannot actually parse HLO text offline).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let _ = path.as_ref();
        Err(Error::unavailable("parsing HLO text"))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("fetching device buffers"))
    }
}

/// A compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("executing compiled modules"))
    }
}

/// The PJRT CPU client.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compiling HLO modules"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_shape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        let s = r.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 6);
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn into_reshape_moves_payload() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let ptr = match &l {
            Literal::F32 { data, .. } => data.as_ptr(),
            _ => unreachable!(),
        };
        let r = l.into_reshape(&[2, 2]).unwrap();
        match &r {
            Literal::F32 { data, dims } => {
                assert_eq!(data.as_ptr(), ptr); // moved, not cloned
                assert_eq!(dims, &[2, 2]);
            }
            _ => unreachable!(),
        }
        assert!(r.into_reshape(&[5]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[42i32]).reshape(&[]).unwrap();
        let s = l.array_shape().unwrap();
        assert!(s.dims().is_empty());
        assert_eq!(s.ty(), ElementType::S32);
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn execution_surface_errors_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let comp = XlaComputation { _private: () };
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("offline xla stub"));
    }
}
