//! Minimal offline stand-in for the `anyhow` crate (DESIGN.md §6).
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides exactly the surface the zipcache crate uses: the boxed-message
//! [`Error`] type, the defaulted [`Result`] alias, and the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros.  Like the real crate, `Error` does
//! *not* implement `std::error::Error` itself — that keeps the blanket
//! `From<E: std::error::Error>` conversion coherent, which is what makes
//! `?` work on `io::Error`, `ParseIntError`, and friends.
//!
//! Intentionally omitted (unused in this repo): backtraces, `Context`,
//! downcasting, and error chaining.

use std::fmt;

/// A boxed error message, convertible from any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        Ok(s.parse::<u32>()?) // blanket From<ParseIntError>
    }

    fn guarded(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        Ok(x)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} at {}", "thing", 3);
        assert_eq!(e.to_string(), "bad thing at 3");
        let x = 5;
        let e = anyhow!("inline {x}");
        assert_eq!(e.to_string(), "inline 5");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(guarded(2).unwrap(), 2);
        let err = guarded(-1).unwrap_err();
        assert!(err.to_string().contains("positive"));
    }
}
